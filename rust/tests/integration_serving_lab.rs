//! Integration tests over the serving engine on the lab backend: the
//! artifact-free pure-Rust runtime whose decode steps run per-slot paged
//! attention requests through the kernel registry. Unlike the PJRT suite
//! (integration_runtime.rs), these tests always run — the lab backend
//! needs no compiled artifacts — so the engine's scheduling, guard-replay
//! and metrics behaviour is exercised in every `cargo test`.

use pasa::attention::Allocation;
use pasa::coordinator::{
    Engine, EngineConfig, FinishReason, GenParams, GuardPolicy, KvStore, Request, SeqCache,
};
use pasa::model::{ModelDims, Sampling};
use pasa::runtime::{LabModel, NormMode};
use pasa::tensor::Matrix;
use pasa::workloads::Pcg64;

fn tiny_dims(n_layers: usize) -> ModelDims {
    ModelDims {
        vocab_size: 259,
        d_model: 16,
        n_layers,
        n_heads: 2,
        d_head: 8,
        d_ff: 32,
        max_seq: 32,
        prefill_seq: 16,
        decode_batch: 2,
        pad: 256,
        bos: 257,
        eos: 258,
    }
}

fn lab_cfg(policy: GuardPolicy) -> EngineConfig {
    EngineConfig {
        policy,
        kv_pages: 64,
        page_tokens: 8,
        max_queue: 16,
        ..EngineConfig::default()
    }
}

fn gen(max_new_tokens: usize) -> GenParams {
    GenParams {
        max_new_tokens,
        sampling: Sampling::Greedy,
        stop_at_eos: false,
    }
}

#[test]
fn lab_engine_completes_batches_under_every_policy() {
    for policy in [
        GuardPolicy::AlwaysPasa,
        GuardPolicy::AlwaysFa16,
        GuardPolicy::AlwaysFa32,
        GuardPolicy::Adaptive,
    ] {
        let model = LabModel::synthetic(tiny_dims(2), 42);
        let mut eng = Engine::from_lab(model, lab_cfg(policy));
        for i in 0..5 {
            let id = eng.fresh_id();
            eng.submit(Request::new(id, format!("prompt {i}")).with_params(gen(6)));
        }
        let comps = eng.run_to_completion().unwrap();
        assert_eq!(comps.len(), 5, "{policy:?}");
        for c in &comps {
            assert_eq!(c.reason, FinishReason::MaxTokens, "{policy:?}");
            assert_eq!(c.tokens.len(), 6, "{policy:?}");
        }
        assert!(eng.idle());
        assert_eq!(eng.kv_utilization(), 0.0, "{policy:?}: pages leaked");
        // Every decode step (there are no replays on a clean workload)
        // left a latency sample.
        assert_eq!(
            eng.metrics.step_latency.count() as u64,
            eng.metrics.decode_steps,
            "{policy:?}"
        );
    }
}

#[test]
fn completion_timing_splits_queue_wait_from_prefill() {
    // Regression (PR 2): queue_time used to be arrival → prefill_done
    // (prefill execution counted as queueing) and prefill_time was
    // assigned the very same value. The invariant pinned here:
    //   queue_time + prefill_time ≤ ttft  (first token samples after
    //   prefill) and the gap is small.
    let model = LabModel::synthetic(tiny_dims(2), 43);
    let mut eng = Engine::from_lab(model, lab_cfg(GuardPolicy::AlwaysFa32));
    // 4 requests over 2 slots: the later ones must actually queue.
    for i in 0..4 {
        let id = eng.fresh_id();
        eng.submit(Request::new(id, format!("wait {i}")).with_params(gen(8)));
    }
    let comps = eng.run_to_completion().unwrap();
    assert_eq!(comps.len(), 4);
    for c in &comps {
        assert!(c.queue_time >= 0.0);
        assert!(c.prefill_time > 0.0, "prefill_time must be a real duration");
        let qp = c.queue_time + c.prefill_time;
        assert!(
            qp <= c.first_token_latency + 1e-9,
            "queue {} + prefill {} exceeds ttft {}",
            c.queue_time,
            c.prefill_time,
            c.first_token_latency
        );
        assert!(
            c.first_token_latency - qp < 0.25,
            "ttft {} unexplained by queue {} + prefill {}",
            c.first_token_latency,
            c.queue_time,
            c.prefill_time
        );
    }
    // The queued pair waited for a decode round while the first pair
    // held both slots, so their queue_time is strictly positive.
    let queued: Vec<_> = comps.iter().filter(|c| c.queue_time > 0.0).collect();
    assert!(
        queued.len() >= 2,
        "expected the 3rd/4th request to report queue wait"
    );
}

/// The deterministic overflow-probe model (see runtime/lab.rs NormMode
/// docs): 1 layer, identity norm, and a positional spike at `P_STAR` that
/// drives the *query* (only) of that position to `AMP`, so the raw score
/// row at `P_STAR` is ≈ 8·AMP·0.5 — past the FP16 boundary for FA16-32
/// while PASA's pseudo-average shift absorbs it. K/V projections read the
/// un-spiked channels, so cached rows stay benign and no later step
/// overflows. Token 100 gets a +0.3 logit bias so greedy decoding is
/// margin-robust across allocations at every benign step.
const P_STAR: usize = 12;
const AMP: f32 = 30_000.0;

fn probe_model() -> LabModel {
    let dims = tiny_dims(1);
    let mut m = LabModel::synthetic(dims, 0xBEEF);
    m.norm = NormMode::Identity;
    // tok_emb: small noise, one dominant "next token" direction.
    let mut rng = Pcg64::new(1234, 0);
    for v in &mut m.tok_emb.data {
        *v = rng.normal(0.0, 0.01) as f32;
    }
    for j in 0..8 {
        let old = m.tok_emb.at(100, j);
        m.tok_emb.set(100, j, old + 0.3);
    }
    // pos_emb: 0.5 everywhere; the query channels (8..16) spike at P_STAR.
    for v in &mut m.pos_emb.data {
        *v = 0.5;
    }
    for j in 8..16 {
        m.pos_emb.set(P_STAR, j, AMP);
    }
    let lw = &mut m.layers[0];
    // Q reads the spiked channels 8..16; K and V read the benign 0..8.
    lw.wq = Matrix::zeros(16, 16);
    lw.wk = Matrix::zeros(16, 16);
    for j in 0..8 {
        lw.wq.set(8 + j, j, 1.0); // head 0
        lw.wq.set(8 + j, 8 + j, 1.0); // head 1
        lw.wk.set(j, j, 1.0);
        lw.wk.set(j, 8 + j, 1.0);
    }
    lw.wv = lw.wk.clone();
    // Attention output feeds the residual stream (and thus the logits).
    let mut wo = Matrix::zeros(16, 16);
    for i in 0..16 {
        wo.set(i, i, 0.1);
    }
    lw.wo = wo;
    // MLP is a no-op so the probe arithmetic stays analyzable.
    lw.w1 = Matrix::zeros(16, 32);
    lw.b1 = vec![0.0; 32];
    lw.w2 = Matrix::zeros(32, 16);
    lw.b2 = vec![0.0; 16];
    m
}

/// Dense readback of one engine slot's paged cache (layer 0, K then V).
fn read_slot_cache(eng: &Engine<'_>, slot: usize) -> (Vec<f32>, Vec<f32>) {
    let pool = eng.kv_pool();
    let cache: &SeqCache = eng.slot_cache(slot).expect("slot occupied");
    let w = 16;
    let mut k = vec![0.0f32; cache.len_tokens * w];
    let mut v = vec![0.0f32; cache.len_tokens * w];
    cache.fill_dense(pool, 0, false, &mut k).unwrap();
    cache.fill_dense(pool, 0, true, &mut v).unwrap();
    (k, v)
}

#[test]
fn guard_replay_pins_one_slot_and_matches_an_always_pasa_cache() {
    // Two engines over the identical probe model and workload: one
    // adaptive, one pinned to PASA from the start. Slot 0's request
    // crosses P_STAR (its decode round overflows FA16-32, is replayed
    // under PASA, and the slot is pinned); slot 1 finishes below P_STAR
    // and must stay on the fast path. After the replay the adaptive
    // engine's paged cache must be bit-identical to the never-overflowed
    // PASA engine's — replay is exact, cache-in → cache-out.
    let mut adaptive = Engine::from_lab(probe_model(), lab_cfg(GuardPolicy::Adaptive));
    let mut reference = Engine::from_lab(probe_model(), lab_cfg(GuardPolicy::AlwaysPasa));
    for eng in [&mut adaptive, &mut reference] {
        let a = eng.fresh_id();
        // 7 bytes + BOS: prefill n = 8, decode positions 8, 9, ... cross
        // P_STAR = 12 at the 5th decode round.
        eng.submit(Request::new(a, "aaaaaaa").with_params(gen(20)));
        let b = eng.fresh_id();
        // 2 bytes + BOS: positions 3..=10 stay below P_STAR.
        eng.submit(Request::new(b, "zz").with_params(gen(8)));
    }
    // Step both engines 10 rounds: the overflow fires at round 5; slot 1
    // retires at round 8; slot 0 is still decoding at round 10.
    for _ in 0..10 {
        adaptive.step().unwrap();
        reference.step().unwrap();
    }

    // Premises: the trip actually happened, exactly once, on slot 0 only.
    assert_eq!(adaptive.metrics.guard_switches, 1, "expected one guard trip");
    assert!(adaptive.metrics.overflow_steps >= 1);
    assert_eq!(adaptive.slot_allocation(0), Some("pasa"), "slot 0 pinned");
    assert_eq!(
        adaptive.slot_allocation(1),
        None,
        "slot 1 finished below P_STAR without pinning"
    );
    assert_eq!(reference.metrics.guard_switches, 0);

    // The replayed round ran one extra decode step, and every step —
    // including the replay — left a latency sample (PR 2 satellite:
    // replays used to be missing from step_latency).
    assert_eq!(
        adaptive.metrics.decode_steps,
        reference.metrics.decode_steps + 1
    );
    assert_eq!(
        adaptive.metrics.step_latency.count() as u64,
        adaptive.metrics.decode_steps
    );

    // The acceptance bit: the adaptive engine's paged cache for the
    // replayed slot is bit-identical to the never-overflowed PASA run.
    let (ka, va) = read_slot_cache(&adaptive, 0);
    let (kr, vr) = read_slot_cache(&reference, 0);
    assert_eq!(ka, kr, "K cache diverged from the PASA reference");
    assert_eq!(va, vr, "V cache diverged from the PASA reference");
    assert!(ka.iter().all(|x| x.is_finite()), "NaN leaked into the cache");

    // And the generated tokens agree end-to-end.
    let ca = adaptive.run_to_completion().unwrap();
    let cr = reference.run_to_completion().unwrap();
    let find = |cs: &[pasa::coordinator::Completion], id: u64| {
        cs.iter().find(|c| c.id == id).unwrap().clone()
    };
    for id in [1u64, 2] {
        let a = find(&ca, id);
        let r = find(&cr, id);
        assert_eq!(a.tokens, r.tokens, "request {id} tokens diverged");
    }
    let slot_a = find(&ca, 1);
    assert_eq!(slot_a.allocation, "pasa");
    assert_eq!(slot_a.guard_switches, 1);
    let slot_b = find(&ca, 2);
    assert_eq!(slot_b.allocation, "fa16_32");
    assert_eq!(slot_b.guard_switches, 0);
}

/// Two-spike variant of the probe model for the pre-emptive guard: a
/// *pressure* spike at `P_PRESS` (score ≈ 4·12000 = 48000 — inside FP16
/// but past 0.6·65504 ≈ 39302) followed by the overflow spike at `P_STAR`
/// (score ≈ 120000). A Preemptive(0.6) guard pins at `P_PRESS` with the
/// step still exact — zero replays — so `P_STAR` already runs PASA;
/// Adaptive sees nothing at `P_PRESS` and must replay `P_STAR`.
const P_PRESS: usize = 10;
const AMP_PRESS: f32 = 12_000.0;

fn pressure_probe_model() -> LabModel {
    let mut m = probe_model();
    for j in 8..16 {
        m.pos_emb.set(P_PRESS, j, AMP_PRESS);
    }
    m
}

#[test]
fn preemptive_guard_pins_on_pressure_with_zero_replays() {
    // Three engines, identical staged workload crossing P_PRESS then
    // P_STAR: the pre-emptive engine must finish with zero overflow steps
    // and zero replays (decode_steps equal to an always-PASA run), while
    // the adaptive engine overflows at P_STAR and pays one replay.
    let preemptive_policy = GuardPolicy::Preemptive {
        score_limit_frac: 0.6,
    };
    let mut preemptive = Engine::from_lab(pressure_probe_model(), lab_cfg(preemptive_policy));
    let mut adaptive = Engine::from_lab(pressure_probe_model(), lab_cfg(GuardPolicy::Adaptive));
    let mut reference =
        Engine::from_lab(pressure_probe_model(), lab_cfg(GuardPolicy::AlwaysPasa));
    for eng in [&mut preemptive, &mut adaptive, &mut reference] {
        let id = eng.fresh_id();
        // 7 bytes + BOS: decode positions 8, 9, ... cross P_PRESS = 10
        // and then P_STAR = 12.
        eng.submit(Request::new(id, "aaaaaaa").with_params(gen(20)));
    }
    let cp = preemptive.run_to_completion().unwrap();
    let ca = adaptive.run_to_completion().unwrap();
    let cr = reference.run_to_completion().unwrap();

    // Pre-emptive: pinned once, on pressure — no overflow ever reached a
    // store, and no step was replayed.
    assert_eq!(preemptive.metrics.guard_switches, 1, "one pressure pin");
    assert_eq!(
        preemptive.metrics.overflow_steps, 0,
        "pre-emptive must pin before the first poisoned step"
    );
    assert_eq!(
        preemptive.metrics.decode_steps, reference.metrics.decode_steps,
        "zero replayed steps: same step count as always-PASA"
    );

    // Adaptive on the same staging: the overflow lands first, one replay.
    assert_eq!(adaptive.metrics.guard_switches, 1);
    assert!(adaptive.metrics.overflow_steps >= 1, "adaptive takes the hit");
    assert_eq!(
        adaptive.metrics.decode_steps,
        reference.metrics.decode_steps + 1,
        "adaptive pays exactly one replayed step"
    );

    // All three engines serve the same tokens (greedy + logit margin).
    assert_eq!(cp[0].tokens, cr[0].tokens, "preemptive tokens diverged");
    assert_eq!(ca[0].tokens, cr[0].tokens, "adaptive tokens diverged");
    assert_eq!(cp[0].allocation, "pasa");
    assert_eq!(cp[0].guard_switches, 1);
}

/// FP8-chain variant of the probe: the positional query spike is sized so
/// the raw score at `P_STAR` is ≈ 8·300·0.5 = 1200 — past E4M3's 448 but
/// far inside FP16 — and is a pure sequence-dim *bias* (every cached K row
/// is ≈ the same benign vector), exactly what the pseudo-average shift
/// removes. An engine started on the FP8 row must therefore rescue the
/// tripped step under **Pasa8** and finish the request without ever
/// leaving the 8-bit envelope.
const AMP_8BIT: f32 = 300.0;

fn fp8_probe_model() -> LabModel {
    let mut m = probe_model();
    for j in 8..16 {
        m.pos_emb.set(P_STAR, j, AMP_8BIT);
    }
    m
}

#[test]
fn fp8_start_engine_rescues_within_8bit_via_pasa8() {
    let mut cfg = lab_cfg(GuardPolicy::Adaptive);
    cfg.start_alloc = Allocation::Fp8;
    let mut eng = Engine::from_lab(fp8_probe_model(), cfg);
    // Independent baseline for the replay count: a guard pinned to Pasa8
    // from step one walks the identical workload with zero replays (the
    // shifted store never trips), so its decode_steps is the replay-free
    // round count. max_new is fixed and stop_at_eos is off, so both
    // engines run the same number of rounds regardless of which tokens
    // greedy sampling picks.
    let mut ref_cfg = lab_cfg(GuardPolicy::Adaptive);
    ref_cfg.start_alloc = Allocation::Pasa8;
    let mut reference = Engine::from_lab(fp8_probe_model(), ref_cfg);
    for e in [&mut eng, &mut reference] {
        let id = e.fresh_id();
        // 7 bytes + BOS: decode positions 8, 9, ... cross P_STAR = 12.
        e.submit(Request::new(id, "aaaaaaa").with_params(gen(20)));
    }
    let comps = eng.run_to_completion().unwrap();
    reference.run_to_completion().unwrap();
    assert_eq!(comps.len(), 1);
    let c = &comps[0];
    assert_eq!(c.reason, FinishReason::MaxTokens);
    assert_eq!(c.tokens.len(), 20);
    // One trip: fp8 → pasa8, and the chain never had to abandon 8-bit —
    // the completion's allocation is Pasa8, not full FP16 PASA.
    assert_eq!(c.allocation, "pasa8", "rescue must stay within 8-bit");
    assert_eq!(c.guard_switches, 1, "exactly one chain step");
    assert_eq!(eng.metrics.guard_switches, 1);
    assert!(
        eng.metrics.overflow_steps >= 1,
        "the 448 trip must be recorded"
    );
    // The Pasa8 baseline premise: no trips, no replays on the same ramp.
    assert_eq!(reference.metrics.guard_switches, 0, "baseline must not trip");
    assert_eq!(reference.metrics.overflow_steps, 0);
    // Exactly one replayed decode step: the fp8 walk pays the baseline's
    // round count plus the single tripped-round rerun.
    assert_eq!(
        eng.metrics.decode_steps,
        reference.metrics.decode_steps + 1,
        "the chain rescue must cost exactly one replayed step"
    );
    assert_eq!(
        eng.metrics.step_latency.count() as u64,
        eng.metrics.decode_steps
    );
    assert!(eng.idle());
    assert_eq!(eng.kv_utilization(), 0.0, "pages leaked");
}

#[test]
fn fp8_probe_premise_trips_448_but_not_fp16() {
    // Premise pin for the chain test: an engine *fixed* to FA16-32 on the
    // same staged workload never overflows (1200 ≪ 65504), while a
    // guard-free FP8 row does trip at P_STAR — the overflow site really
    // is the E4M3 boundary, not FP16's.
    let mut eng = Engine::from_lab(fp8_probe_model(), lab_cfg(GuardPolicy::AlwaysFa16));
    let id = eng.fresh_id();
    eng.submit(Request::new(id, "aaaaaaa").with_params(gen(20)));
    eng.run_to_completion().unwrap();
    assert_eq!(eng.metrics.overflow_steps, 0, "FP16 must hold the spike");
    assert_eq!(eng.metrics.guard_switches, 0);
}

#[test]
fn probe_premise_fa16_32_overflows_only_at_p_star() {
    // Sanity for the probe construction itself: an AlwaysFa16 engine on
    // the short prompt never overflows; on the long prompt it poisons
    // exactly when position P_STAR is decoded.
    let model = probe_model();
    let mut eng = Engine::from_lab(model, lab_cfg(GuardPolicy::AlwaysFa16));
    let id = eng.fresh_id();
    eng.submit(Request::new(id, "zz").with_params(gen(8)));
    eng.run_to_completion().unwrap();
    assert_eq!(eng.metrics.overflow_steps, 0, "short prompt must stay clean");

    let model = probe_model();
    let mut eng = Engine::from_lab(model, lab_cfg(GuardPolicy::AlwaysFa16));
    let id = eng.fresh_id();
    eng.submit(Request::new(id, "aaaaaaa").with_params(gen(20)));
    eng.run_to_completion().unwrap();
    // Fixed policy: no replay possible, the overflow surfaces and the
    // poisoned row is visible exactly once (K/V stay benign afterwards).
    assert_eq!(eng.metrics.guard_switches, 0);
    assert_eq!(eng.metrics.overflow_steps, 1, "overflow must fire once, at P_STAR");
}

/// Dims for the KV-residency test: tiny_dims with an 8-wide decode batch
/// so the slot cap is never the binding constraint — page capacity is.
fn residency_dims() -> ModelDims {
    ModelDims {
        decode_batch: 8,
        ..tiny_dims(2)
    }
}

/// Engine over `residency_dims` with a deliberately tight KV byte budget:
/// `kv_pages` is denominated in **f32 pages** (EngineConfig docs), so both
/// stores get `8 pages × 16 tokens × width 16 × 4 B = 8 KiB` of arena and
/// only the page *count* differs (8 at f32, 32 at 1-byte E4M3).
fn residency_engine(store: KvStore) -> Engine<'static> {
    let cfg = EngineConfig {
        policy: GuardPolicy::AlwaysFa32,
        kv_pages: 8,
        page_tokens: 16,
        max_queue: 16,
        kv_store: store,
        ..EngineConfig::default()
    };
    Engine::from_lab(LabModel::synthetic(residency_dims(), 42), cfg)
}

#[test]
fn e4m3_kv_store_doubles_residency_at_fixed_byte_budget() {
    // The tentpole acceptance bit for E4M3 KV storage: at a *fixed byte
    // budget*, 1-byte pages must at least double the number of
    // concurrently resident sequences. Each request below commits
    // prompt(3 bytes + BOS = 4) + max_new(12) = 16 tokens — exactly one
    // 16-token page per K/V chain, i.e. 2 layers × (K+V) = 4 pages, all
    // of them allocated by the first prefill chunk. One page per chain
    // means a slot never grows after admission, so the admission page
    // check is exact (pages allocate lazily; a multi-page commitment
    // could over-admit and then evict mid-decode). The f32 pool (8
    // pages) therefore seats exactly 2 sequences at a time and the E4M3
    // pool (32 pages in the same 8 KiB) seats all 8.
    let mut f32_eng = residency_engine(KvStore::F32);
    let mut e4m3_eng = residency_engine(KvStore::E4m3);
    assert_eq!(
        e4m3_eng.kv_pool().total_pages(),
        4 * f32_eng.kv_pool().total_pages(),
        "1-byte pages must quadruple the page count at a fixed byte budget"
    );

    let mut peaks = [0usize; 2];
    for (eng, peak) in [&mut f32_eng, &mut e4m3_eng].into_iter().zip(&mut peaks) {
        for _ in 0..8 {
            let id = eng.fresh_id();
            eng.submit(Request::new(id, "abc").with_params(gen(12)));
        }
        let mut comps = Vec::new();
        while !eng.idle() {
            eng.step().unwrap();
            *peak = (*peak).max(eng.active_count());
            comps.extend(eng.take_completions());
        }
        // Residency never changes correctness: every request runs to its
        // token budget, and no pages leak on either store.
        assert_eq!(comps.len(), 8);
        for c in &comps {
            assert_eq!(c.reason, FinishReason::MaxTokens);
            assert_eq!(c.tokens.len(), 12);
        }
        assert_eq!(eng.kv_utilization(), 0.0, "pages leaked");
    }

    let [peak_f32, peak_e4m3] = peaks;
    assert_eq!(peak_f32, 2, "f32 premise: page capacity binds at 2 resident");
    assert!(
        peak_e4m3 >= 2 * peak_f32,
        "E4M3 must at least double residency: {peak_e4m3} vs {peak_f32}"
    );
    // The f32 engine had to defer admissions on KV pages; the E4M3 engine
    // never did — the whole workload fit at once.
    assert!(
        f32_eng.metrics.deferrals.kv_pages > 0,
        "f32 premise: the workload must actually hit KV backpressure"
    );
    assert_eq!(
        e4m3_eng.metrics.deferrals.kv_pages, 0,
        "E4M3 run must admit the whole workload without KV deferrals"
    );
}
