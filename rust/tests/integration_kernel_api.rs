//! Acceptance tests for the unified AttentionKernel API: masked, GQA and
//! batched-padded requests through every precision allocation, verified
//! against the masked full-precision golden reference.

use pasa::attention::{Allocation, AttentionRequest, AttnMask, KernelRegistry};
use pasa::coordinator::{Guard, GuardPolicy, GuardSignal};
use pasa::numerics::relative_rmse;
use pasa::workloads::{
    gen_gqa_multihead, gen_multihead, gen_padded_multihead, Distribution, Pcg64,
};

/// RMSE envelopes per allocation against the FP32 golden reference, at the
/// scale of the repo's existing kernel tests (FA32 tracks the golden to
/// f32 accuracy; the FP16 paths sit at the paper's Table 3 / Fig. 9
/// low-precision error level, observed ≤ a few 1e-2 relative).
fn envelope(alloc: Allocation) -> f64 {
    match alloc {
        Allocation::Fa32 => 1e-5,
        _ => 5e-2,
    }
}

#[test]
fn masked_multihead_matches_masked_naive_for_all_allocations() {
    // Acceptance: masked multi-head cases pass RMSE checks against the
    // masked naive FP32 reference for every allocation, all through
    // KernelRegistry — no per-callsite dispatch.
    let mh = gen_multihead(Distribution::Uniform { x0: 1.0, am: 1.0 }, 4, 96, 32, 21);
    for mask in [AttnMask::None, AttnMask::Causal] {
        let base = AttentionRequest::from_multihead(&mh, Allocation::Fa32)
            .with_mask(mask.clone())
            .with_blocks(32, 32)
            .with_fp16_inputs();
        let golden = KernelRegistry::naive().forward(&base);
        for alloc in Allocation::all() {
            let out = base.clone().with_alloc(alloc).run();
            assert!(!out.overflowed(), "{} {:?} overflowed", alloc.name(), mask);
            for h in 0..4 {
                let e = relative_rmse(&out.heads[h].data, &golden.heads[h].data);
                assert!(
                    e < envelope(alloc),
                    "{} {:?} head {h}: rmse {e}",
                    alloc.name(),
                    mask
                );
            }
        }
    }
}

#[test]
fn gqa_masked_matches_naive_for_all_allocations() {
    // 8 query heads over 2 KV heads, causal, every allocation.
    let mh = gen_gqa_multihead(Distribution::Uniform { x0: 2.0, am: 1.0 }, 8, 2, 64, 64, 16, 22);
    let base = AttentionRequest::from_multihead(&mh, Allocation::Fa32)
        .with_mask(AttnMask::Causal)
        .with_blocks(32, 32)
        .with_fp16_inputs();
    let golden = KernelRegistry::naive().forward(&base);
    for alloc in Allocation::all() {
        let out = base.clone().with_alloc(alloc).run();
        assert_eq!(out.heads.len(), 8);
        for h in 0..8 {
            let e = relative_rmse(&out.heads[h].data, &golden.heads[h].data);
            assert!(e < envelope(alloc), "{} head {h}: rmse {e}", alloc.name());
        }
    }
}

#[test]
fn gqa_bit_matches_the_single_head_path() {
    // Acceptance: an 8-query-head / 2-kv-head case must bit-match running
    // each query head against its mapped KV head through the single-head
    // path — for the flash allocations AND PASA (whose kernel shares K'
    // preprocessing across the GQA group; sharing must not change bits).
    let mh = gen_gqa_multihead(Distribution::Uniform { x0: 3.0, am: 1.0 }, 8, 2, 96, 96, 16, 23);
    for mask in [AttnMask::None, AttnMask::Causal] {
        for alloc in Allocation::all() {
            let req = AttentionRequest::from_multihead(&mh, alloc)
                .with_mask(mask.clone())
                .with_blocks(32, 32)
                .with_fp16_inputs();
            let out = req.run();
            for h in 0..8 {
                let solo = AttentionRequest::from_case_cfg(&req.head_case(h), req.cfg)
                    .with_mask(mask.clone())
                    .run();
                assert_eq!(
                    out.heads[h].data,
                    solo.heads[0].data,
                    "{} {:?} head {h} diverged from the single-head path",
                    alloc.name(),
                    mask
                );
            }
        }
    }
}

#[test]
fn padded_batch_with_garbage_padding_is_rescued_by_the_mask() {
    // Mask-aware generation fills the padding region with values that
    // guarantee FP16 overflow if read; the Padded mask must exclude them
    // for every allocation, and per-head outputs must match the
    // truncated-KV golden reference.
    let lens = [48usize, 96, 17];
    let mh = gen_padded_multihead(
        Distribution::Uniform { x0: 0.5, am: 1.0 },
        3,
        96,
        32,
        &lens,
        24,
    );
    let base = AttentionRequest::from_multihead(&mh, Allocation::Fa32)
        .with_blocks(32, 32)
        .with_fp16_inputs();
    assert_eq!(base.mask, AttnMask::Padded(vec![48, 96, 17]));
    let golden = KernelRegistry::naive().forward(&base);
    for alloc in Allocation::all() {
        let out = base.clone().with_alloc(alloc).run();
        assert!(!out.overflowed(), "{}: padding leaked", alloc.name());
        assert_eq!(out.overflow_events(), 0, "{}: telemetry leaked", alloc.name());
        for h in 0..3 {
            let e = relative_rmse(&out.heads[h].data, &golden.heads[h].data);
            assert!(e < envelope(alloc), "{} head {h}: rmse {e}", alloc.name());
        }
    }
    // Premise check: without the mask the garbage padding poisons FA16-32.
    let unmasked = base.clone().with_mask(AttnMask::None).with_alloc(Allocation::Fa16_32);
    assert!(unmasked.run().overflowed(), "premise: padding must poison");
}

#[test]
fn fully_masked_rows_never_nan() {
    // Acceptance edge case: a zero-length padded head — softmax over the
    // empty set — must produce zeros, not NaN, in every allocation.
    let mh = gen_padded_multihead(
        Distribution::Uniform { x0: 1.0, am: 1.0 },
        2,
        64,
        16,
        &[0, 32],
        25,
    );
    let base = AttentionRequest::from_multihead(&mh, Allocation::Fa32)
        .with_blocks(32, 32)
        .with_fp16_inputs();
    for alloc in Allocation::all() {
        let out = base.clone().with_alloc(alloc).run();
        assert!(
            out.heads[0].data.iter().all(|&x| x == 0.0),
            "{}: empty softmax must be exactly zero",
            alloc.name()
        );
        assert!(!out.overflowed(), "{}: NaN from empty softmax", alloc.name());
        assert!(
            out.heads[1].data.iter().all(|x| x.is_finite()),
            "{}: valid head poisoned",
            alloc.name()
        );
    }
}

#[test]
fn causal_gqa_decode_shape() {
    // Decode-style request: 1 query row over a long KV (the serving hot
    // path) with MQA (4 query heads, 1 KV head). Causal with s1=1 sees
    // everything; outputs must match the unmasked run exactly.
    let mh = gen_gqa_multihead(Distribution::Uniform { x0: 1.0, am: 1.0 }, 4, 1, 1, 128, 32, 26);
    let dense = AttentionRequest::from_multihead(&mh, Allocation::Pasa16).with_fp16_inputs();
    let causal = dense.clone().with_mask(AttnMask::Causal);
    let a = dense.run();
    let b = causal.run();
    for h in 0..4 {
        assert_eq!(a.heads[h].data, b.heads[h].data, "head {h}");
        assert_eq!(a.heads[h].shape(), (1, 32));
    }
}

#[test]
fn kernel_telemetry_feeds_the_guard() {
    // The coordinator contract: attention-lab telemetry (not logits
    // sniffing) trips the adaptive guard, and the PASA replay of the very
    // same request comes back clean.
    let mut rng = Pcg64::new(27, 0);
    let dist = Distribution::Uniform { x0: 30.0, am: 0.5 };
    let case = pasa::workloads::gen_case(dist, 256, 256, 128, &mut rng);
    let req = AttentionRequest::from_case(&case, Allocation::Fa16_32).with_fp16_inputs();
    let mut guard = Guard::new(GuardPolicy::Adaptive);
    assert_eq!(guard.allocation(), "fa16_32");
    let out = req.run();
    let sig = GuardSignal::from_attention(&out);
    assert!(sig.overflow_events > 0);
    assert!(guard.observe_signal(&sig), "guard must request a replay");
    assert_eq!(guard.allocation(), "pasa");
    let replay = req.with_alloc(Allocation::Pasa16).run();
    let clean = GuardSignal::from_attention(&replay);
    assert!(clean.is_clean(65504.0));
    assert!(!guard.observe_signal(&clean));
}
