//! Acceptance tests for the unified AttentionKernel API: masked, GQA and
//! batched-padded requests through every precision allocation, verified
//! against the masked full-precision golden reference.

use pasa::attention::{
    Allocation, AttentionRequest, AttnMask, BetaPolicy, KernelRegistry, KvPair, KvView,
};
use pasa::coordinator::{Guard, GuardPolicy, GuardSignal, KvPool, SeqCache};
use pasa::numerics::{relative_rmse, Format};
use pasa::workloads::{
    all_traces, gen_case, gen_gqa_multihead, gen_multihead, gen_padded_multihead,
    gen_paged_decode_case, svd_img2vid_trace, Distribution, MultiHeadCase, Pcg64,
};

/// RMSE envelopes per allocation against the FP32 golden reference, at the
/// scale of the repo's existing kernel tests (FA32 tracks the golden to
/// f32 accuracy; the FP16 paths sit at the paper's Table 3 / Fig. 9
/// low-precision error level, observed ≤ a few 1e-2 relative).
fn envelope(alloc: Allocation) -> f64 {
    match alloc {
        Allocation::Fa32 => 1e-5,
        Allocation::Fa16_32
        | Allocation::Fa16
        | Allocation::Pasa16
        | Allocation::Fp8
        | Allocation::Pasa8 => 5e-2,
    }
}

#[test]
fn masked_multihead_matches_masked_naive_for_all_allocations() {
    // Acceptance: masked multi-head cases pass RMSE checks against the
    // masked naive FP32 reference for every allocation, all through
    // KernelRegistry — no per-callsite dispatch.
    let mh = gen_multihead(Distribution::Uniform { x0: 1.0, am: 1.0 }, 4, 96, 32, 21);
    for mask in [AttnMask::None, AttnMask::Causal] {
        let base = AttentionRequest::from_multihead(&mh, Allocation::Fa32)
            .with_mask(mask.clone())
            .with_blocks(32, 32)
            .with_fp16_inputs();
        let golden = KernelRegistry::naive().forward(&base);
        for alloc in Allocation::all() {
            let out = base.clone().with_alloc(alloc).run();
            assert!(!out.overflowed(), "{} {:?} overflowed", alloc.name(), mask);
            for h in 0..4 {
                let e = relative_rmse(&out.heads[h].data, &golden.heads[h].data);
                assert!(
                    e < envelope(alloc),
                    "{} {:?} head {h}: rmse {e}",
                    alloc.name(),
                    mask
                );
            }
        }
    }
}

#[test]
fn gqa_masked_matches_naive_for_all_allocations() {
    // 8 query heads over 2 KV heads, causal, every allocation.
    let mh = gen_gqa_multihead(Distribution::Uniform { x0: 2.0, am: 1.0 }, 8, 2, 64, 64, 16, 22);
    let base = AttentionRequest::from_multihead(&mh, Allocation::Fa32)
        .with_mask(AttnMask::Causal)
        .with_blocks(32, 32)
        .with_fp16_inputs();
    let golden = KernelRegistry::naive().forward(&base);
    for alloc in Allocation::all() {
        let out = base.clone().with_alloc(alloc).run();
        assert_eq!(out.heads.len(), 8);
        for h in 0..8 {
            let e = relative_rmse(&out.heads[h].data, &golden.heads[h].data);
            assert!(e < envelope(alloc), "{} head {h}: rmse {e}", alloc.name());
        }
    }
}

#[test]
fn gqa_bit_matches_the_single_head_path() {
    // Acceptance: an 8-query-head / 2-kv-head case must bit-match running
    // each query head against its mapped KV head through the single-head
    // path — for the flash allocations AND PASA (whose kernel shares K'
    // preprocessing across the GQA group; sharing must not change bits).
    let mh = gen_gqa_multihead(Distribution::Uniform { x0: 3.0, am: 1.0 }, 8, 2, 96, 96, 16, 23);
    for mask in [AttnMask::None, AttnMask::Causal] {
        for alloc in Allocation::all() {
            let req = AttentionRequest::from_multihead(&mh, alloc)
                .with_mask(mask.clone())
                .with_blocks(32, 32)
                .with_fp16_inputs();
            let out = req.run();
            for h in 0..8 {
                let solo = AttentionRequest::from_case_cfg(&req.head_case(h), req.cfg)
                    .with_mask(mask.clone())
                    .run();
                assert_eq!(
                    out.heads[h].data,
                    solo.heads[0].data,
                    "{} {:?} head {h} diverged from the single-head path",
                    alloc.name(),
                    mask
                );
            }
        }
    }
}

#[test]
fn padded_batch_with_garbage_padding_is_rescued_by_the_mask() {
    // Mask-aware generation fills the padding region with values that
    // guarantee FP16 overflow if read; the Padded mask must exclude them
    // for every allocation, and per-head outputs must match the
    // truncated-KV golden reference.
    let lens = [48usize, 96, 17];
    let mh = gen_padded_multihead(
        Distribution::Uniform { x0: 0.5, am: 1.0 },
        3,
        96,
        32,
        &lens,
        24,
    );
    let base = AttentionRequest::from_multihead(&mh, Allocation::Fa32)
        .with_blocks(32, 32)
        .with_fp16_inputs();
    assert_eq!(base.mask, AttnMask::Padded(vec![48, 96, 17]));
    let golden = KernelRegistry::naive().forward(&base);
    for alloc in Allocation::all() {
        let out = base.clone().with_alloc(alloc).run();
        assert!(!out.overflowed(), "{}: padding leaked", alloc.name());
        assert_eq!(out.overflow_events(), 0, "{}: telemetry leaked", alloc.name());
        for h in 0..3 {
            let e = relative_rmse(&out.heads[h].data, &golden.heads[h].data);
            assert!(e < envelope(alloc), "{} head {h}: rmse {e}", alloc.name());
        }
    }
    // Premise check: without the mask the garbage padding poisons FA16-32.
    let unmasked = base.clone().with_mask(AttnMask::None).with_alloc(Allocation::Fa16_32);
    assert!(unmasked.run().overflowed(), "premise: padding must poison");
}

#[test]
fn fully_masked_rows_never_nan() {
    // Acceptance edge case: a zero-length padded head — softmax over the
    // empty set — must produce zeros, not NaN, in every allocation.
    let mh = gen_padded_multihead(
        Distribution::Uniform { x0: 1.0, am: 1.0 },
        2,
        64,
        16,
        &[0, 32],
        25,
    );
    let base = AttentionRequest::from_multihead(&mh, Allocation::Fa32)
        .with_blocks(32, 32)
        .with_fp16_inputs();
    for alloc in Allocation::all() {
        let out = base.clone().with_alloc(alloc).run();
        assert!(
            out.heads[0].data.iter().all(|&x| x == 0.0),
            "{}: empty softmax must be exactly zero",
            alloc.name()
        );
        assert!(!out.overflowed(), "{}: NaN from empty softmax", alloc.name());
        assert!(
            out.heads[1].data.iter().all(|x| x.is_finite()),
            "{}: valid head poisoned",
            alloc.name()
        );
    }
}

#[test]
fn causal_gqa_decode_shape() {
    // Decode-style request: 1 query row over a long KV (the serving hot
    // path) with MQA (4 query heads, 1 KV head). Causal with s1=1 sees
    // everything; outputs must match the unmasked run exactly.
    let mh = gen_gqa_multihead(Distribution::Uniform { x0: 1.0, am: 1.0 }, 4, 1, 1, 128, 32, 26);
    let dense = AttentionRequest::from_multihead(&mh, Allocation::Pasa16).with_fp16_inputs();
    let causal = dense.clone().with_mask(AttnMask::Causal);
    let a = dense.run();
    let b = causal.run();
    for h in 0..4 {
        assert_eq!(a.heads[h].data, b.heads[h].data, "head {h}");
        assert_eq!(a.heads[h].shape(), (1, 32));
    }
}

// ---- paged KV views (PR 2 tentpole) ---------------------------------

/// Round every matrix of a case onto the FP16 grid, so the paged cache
/// and the dense reference hold *identical* bits.
fn fp16_case(mut mh: MultiHeadCase) -> MultiHeadCase {
    for m in mh
        .q
        .iter_mut()
        .chain(mh.k.iter_mut())
        .chain(mh.v.iter_mut())
    {
        m.round_to(Format::F16);
    }
    mh
}

/// Write the first `rows` packed KV rows of a (single-layer) case into a
/// fresh paged cache.
fn seed_paged(mh: &MultiHeadCase, pool: &mut KvPool, rows: usize) -> SeqCache {
    let (kp, vp) = mh.packed_kv_rows();
    let mut cache = SeqCache::new(1);
    cache.ensure_capacity(pool, rows).unwrap();
    for r in 0..rows {
        cache.write_row(pool, 0, r, kp.row(r), vp.row(r)).unwrap();
    }
    cache
}

/// Per-KV-head paged views over a cache whose rows pack `n_kv` heads of
/// width `d`, truncated to `len` valid tokens.
fn paged_pairs<'a>(
    cache: &'a SeqCache,
    pool: &'a KvPool,
    n_kv: usize,
    d: usize,
    len: usize,
) -> Vec<KvPair<'a>> {
    (0..n_kv)
        .map(|j| KvPair {
            k: KvView::paged(cache.page_ids(0, false), pool, len).col_window(j * d, d),
            v: KvView::paged(cache.page_ids(0, true), pool, len).col_window(j * d, d),
        })
        .collect()
}

/// A query-heads-only clone of a case (K/V come from views).
fn query_request(mh: &MultiHeadCase, alloc: Allocation, mask: AttnMask) -> AttentionRequest {
    let mut req = AttentionRequest::new(alloc).with_mask(mask).with_blocks(16, 16);
    for q in &mh.q {
        req = req.with_query_head(q.clone());
    }
    req
}

#[test]
fn paged_decode_bit_matches_dense_reference_for_all_allocations() {
    // Acceptance: a decode-shaped request (s1 = 1, GQA 4q/2kv) through
    // KvView::Paged must bit-match the dense reference for every
    // allocation. The pool deliberately holds PAD_GARBAGE rows past the
    // valid length — written into real pages — so a pass also proves the
    // view's len_tokens truly fences the stale page tail.
    let (n_heads, n_kv, d, len, max_seq) = (4usize, 2usize, 16usize, 45usize, 64usize);
    let dist = Distribution::Uniform { x0: 1.0, am: 1.0 };
    let mh = fp16_case(gen_paged_decode_case(dist, n_heads, n_kv, len, max_seq, d, 31));
    let mut pool = KvPool::new(64, 8, n_kv * d);
    let cache = seed_paged(&mh, &mut pool, max_seq); // garbage tail included
    for alloc in Allocation::all() {
        let dense = AttentionRequest::from_multihead(&mh, alloc)
            .with_blocks(16, 16)
            .run();
        let paged_req = query_request(&mh, alloc, AttnMask::Padded(vec![len]));
        let pairs = paged_pairs(&cache, &pool, n_kv, d, len);
        let paged = paged_req.run_with_kv(&pairs);
        assert!(!paged.overflowed(), "{}: garbage tail leaked", alloc.name());
        for h in 0..n_heads {
            assert_eq!(
                dense.heads[h].data,
                paged.heads[h].data,
                "{} head {h}: paged != dense",
                alloc.name()
            );
            assert_eq!(
                dense.stats[h].overflow_events,
                paged.stats[h].overflow_events,
                "{} head {h}: telemetry diverged",
                alloc.name()
            );
        }
        // The golden reference agrees through views too.
        let ng = KernelRegistry::naive().forward(&AttentionRequest::from_multihead(&mh, alloc));
        let np = KernelRegistry::naive().forward_kv(&paged_req, &pairs);
        for h in 0..n_heads {
            assert_eq!(ng.heads[h].data, np.heads[h].data, "naive head {h}");
        }
    }
}

#[test]
fn paged_causal_bit_matches_dense_for_all_allocations() {
    // Multi-row causal queries (prefill-shaped) over a paged KV: the
    // causal block-skipping sweep must gather the same pages and produce
    // the same bits as the dense run.
    let (n_heads, n_kv, d, len) = (4usize, 2usize, 16usize, 45usize);
    let dist = Distribution::Uniform { x0: 2.0, am: 1.0 };
    let mh = fp16_case(gen_gqa_multihead(dist, n_heads, n_kv, 8, len, d, 32));
    let mut pool = KvPool::new(64, 8, n_kv * d);
    let cache = seed_paged(&mh, &mut pool, len);
    for alloc in Allocation::all() {
        let dense = AttentionRequest::from_multihead(&mh, alloc)
            .with_mask(AttnMask::Causal)
            .with_blocks(16, 16)
            .run();
        let paged = query_request(&mh, alloc, AttnMask::Causal)
            .run_with_kv(&paged_pairs(&cache, &pool, n_kv, d, len));
        for h in 0..n_heads {
            assert_eq!(
                dense.heads[h].data,
                paged.heads[h].data,
                "{} head {h}: causal paged != dense",
                alloc.name()
            );
        }
    }
}

#[test]
fn paged_views_after_cow_fork_bit_match_and_stay_isolated() {
    // Acceptance: paged attention remains bit-exact across a
    // copy-on-write fork — the fork sees its own writes, the original's
    // attention output is bit-identical before and after, for all four
    // allocations.
    let (d, len) = (16usize, 20usize);
    let dist = Distribution::Uniform { x0: 1.0, am: 1.0 };
    let mh = fp16_case(gen_paged_decode_case(dist, 2, 1, len, 32, d, 33));
    let mut pool = KvPool::new(64, 4, d);
    let mut cache = seed_paged(&mh, &mut pool, len);

    let base_outputs: Vec<_> = Allocation::all()
        .into_iter()
        .map(|alloc| {
            query_request(&mh, alloc, AttnMask::None)
                .run_with_kv(&paged_pairs(&cache, &pool, 1, d, len))
        })
        .collect();

    // Fork, then write through the fork: overwrite row 5 (CoW on a shared
    // page) and append row `len` (fresh page growth).
    let mut fork = cache.fork(&mut pool);
    let new_row: Vec<f32> = (0..d).map(|i| 0.25 * i as f32).collect();
    fork.write_row(&mut pool, 0, 5, &new_row, &new_row).unwrap();
    fork.ensure_capacity(&mut pool, len + 1).unwrap();
    fork.write_row(&mut pool, 0, len, &new_row, &new_row).unwrap();

    // Dense reference for the fork, assembled with fill_dense.
    let w = d;
    let mut kd = vec![0.0f32; 32 * w];
    let mut vd = vec![0.0f32; 32 * w];
    fork.fill_dense(&pool, 0, false, &mut kd).unwrap();
    fork.fill_dense(&pool, 0, true, &mut vd).unwrap();
    let k_dense = pasa::tensor::Matrix::from_vec(32, w, kd).rows_slice(0, len + 1);
    let v_dense = pasa::tensor::Matrix::from_vec(32, w, vd).rows_slice(0, len + 1);

    for (idx, alloc) in Allocation::all().into_iter().enumerate() {
        // Fork: paged vs dense reference.
        let req = query_request(&mh, alloc, AttnMask::None);
        let paged = req.run_with_kv(&paged_pairs(&fork, &pool, 1, d, len + 1));
        let dense = req.run_with_kv(&[KvPair {
            k: KvView::Dense(&k_dense),
            v: KvView::Dense(&v_dense),
        }]);
        for h in 0..2 {
            assert_eq!(
                dense.heads[h].data,
                paged.heads[h].data,
                "{} head {h}: fork paged != dense",
                alloc.name()
            );
        }
        // Original: bit-identical to the pre-fork run.
        let again = query_request(&mh, alloc, AttnMask::None)
            .run_with_kv(&paged_pairs(&cache, &pool, 1, d, len));
        for h in 0..2 {
            assert_eq!(
                base_outputs[idx].heads[h].data,
                again.heads[h].data,
                "{} head {h}: fork write leaked into the original",
                alloc.name()
            );
        }
    }
    fork.release(&mut pool);
    cache.release(&mut pool);
    assert_eq!(pool.used_pages(), 0);
}

#[test]
fn kernel_telemetry_feeds_the_guard() {
    // The coordinator contract: attention-lab telemetry (not logits
    // sniffing) trips the adaptive guard, and the PASA replay of the very
    // same request comes back clean.
    let mut rng = Pcg64::new(27, 0);
    let dist = Distribution::Uniform { x0: 30.0, am: 0.5 };
    let case = pasa::workloads::gen_case(dist, 256, 256, 128, &mut rng);
    let req = AttentionRequest::from_case(&case, Allocation::Fa16_32).with_fp16_inputs();
    let mut guard = Guard::new(GuardPolicy::Adaptive);
    assert_eq!(guard.allocation(), "fa16_32");
    let out = req.run();
    let sig = GuardSignal::from_attention(&out);
    assert!(sig.overflow_events > 0);
    assert_eq!(sig.boundary, 65504.0, "FP16 allocation carries its boundary");
    assert!(guard.observe_signal(&sig), "guard must request a replay");
    assert_eq!(guard.allocation(), "pasa");
    let replay = req.with_alloc(Allocation::Pasa16).run();
    let clean = GuardSignal::from_attention(&replay);
    assert!(clean.is_clean(1.0));
    assert!(!guard.observe_signal(&clean));
}

// ---- precision policy (PR 3 tentpole) --------------------------------

#[test]
fn beta_autotune_workflow_end_to_end() {
    // The β-autotune workflow: probe once, feed the observed per-head
    // max |S| through the Table 3 solver, rerun PASA under the per-head
    // table. A benign head and a hot head must come out with different
    // solved βs (hotter head shifts harder), and the tuned run must stay
    // clean and near the golden.
    let mut rng = Pcg64::new(61, 0);
    let benign = gen_case(Distribution::Uniform { x0: 0.0, am: 1.0 }, 128, 128, 64, &mut rng);
    let hot = gen_case(Distribution::Uniform { x0: 20.0, am: 0.5 }, 128, 128, 64, &mut rng);
    let req = AttentionRequest::new(Allocation::Pasa16)
        .with_head(benign.q, benign.k, benign.v)
        .with_head(hot.q, hot.k, hot.v)
        .with_fp16_inputs();

    // 1. Probe: the golden's stats carry the raw per-head score peaks.
    let probe = KernelRegistry::naive().forward(&req);
    assert!(probe.stats[1].max_abs_score > 10.0 * probe.stats[0].max_abs_score);

    // 2. Autotune: per-head β table off the probe telemetry.
    let policy = BetaPolicy::autotune(&probe.stats, req.cfg.blocks.s2, Format::F16);
    let BetaPolicy::PerHead(betas) = &policy else {
        panic!("autotune must produce a PerHead table");
    };
    assert_eq!(betas.len(), 2);
    assert!(
        betas[1] > betas[0],
        "hot head must solve a stronger β: {betas:?}"
    );
    for &b in betas {
        assert!((0.9..1.0).contains(&b), "solved β {b} off the paper grid");
    }

    // 3. Rerun under the tuned policy: clean, and near the golden.
    let out = req.clone().with_policy(policy).run();
    assert!(!out.overflowed());
    assert_eq!(out.overflow_events(), 0);
    for h in 0..2 {
        let e = relative_rmse(&out.heads[h].data, &probe.heads[h].data);
        assert!(e < 5e-2, "head {h}: tuned rmse {e}");
    }
}

#[test]
fn video_shaped_tall_kv_gqa_pasa_survives_where_fa16_overflows() {
    // SVD-style video head through the masked path: tall-KV GQA (8 query
    // heads over 2 KV heads, s1 = 16 ≪ s2 = 4096) built from the
    // resonance trace generator. FA16-32 overflows its FP16 score store;
    // PASA on the very same request stays finite with zero pre-store
    // events, its shifted scores inside the FP16 range.
    let mut spec = svd_img2vid_trace(1).spec;
    spec.s1 = 16;
    spec.s2 = 4096;
    let c0 = spec.generate(41);
    let c1 = spec.generate(42);
    let mut req = AttentionRequest::new(Allocation::Fa16_32)
        .with_kv_head(c0.k.clone(), c0.v.clone())
        .with_kv_head(c1.k.clone(), c1.v.clone());
    for _ in 0..4 {
        req = req.with_query_head(c0.q.clone());
    }
    for _ in 0..4 {
        req = req.with_query_head(c1.q.clone());
    }
    let req = req
        .with_mask(AttnMask::Causal)
        .with_blocks(16, 128)
        .with_fp16_inputs();
    assert!(req.validate().is_ok());

    let fa = req.run();
    assert!(
        fa.overflow_events() > 0,
        "premise: the video trace must overflow FA16-32's store"
    );
    assert!(fa.max_abs_score() > 65504.0);

    let pasa = req.clone().with_alloc(Allocation::Pasa16).run();
    assert!(!pasa.overflowed(), "PASA must stay finite on video heads");
    assert_eq!(pasa.overflow_events(), 0, "PASA pre-store events leaked");
    assert_eq!(pasa.nonfinite_outputs(), 0);
    assert!(
        pasa.max_abs_score() < 65504.0,
        "shifted scores must fit FP16: {}",
        pasa.max_abs_score()
    );
}

// ---- Pasa8: shifting into the E4M3 envelope (PR 5 tentpole) -----------

#[test]
fn svd_tall_kv_gqa_pasa8_rescues_at_the_448_boundary() {
    // The SVD-resonance rescue regression re-staged at the E4M3 boundary:
    // the video-shaped tall-KV GQA case (8 query heads over 2 KV heads,
    // s1 = 16 ≪ s2 = 4096) with the trace's amplitudes and biases scaled
    // to 15% — raw score peaks land in the low thousands, comfortably
    // inside FP16 but past E4M3's 448. The plain FP8 row trips its store;
    // Pasa8 on the very same request shifts the coherent bias/resonance
    // away *before* the E4M3 store and survives with zero pre-store
    // events.
    let mut spec = svd_img2vid_trace(1).spec;
    spec.s1 = 16;
    spec.s2 = 4096;
    spec.amp_q *= 0.15;
    spec.amp_k *= 0.15;
    spec.bias_q *= 0.15;
    spec.bias_k *= 0.15;
    let c0 = spec.generate(41);
    let c1 = spec.generate(42);
    let mut req = AttentionRequest::new(Allocation::Fp8)
        .with_kv_head(c0.k.clone(), c0.v.clone())
        .with_kv_head(c1.k.clone(), c1.v.clone());
    for _ in 0..4 {
        req = req.with_query_head(c0.q.clone());
    }
    for _ in 0..4 {
        req = req.with_query_head(c1.q.clone());
    }
    let req = req
        .with_mask(AttnMask::Causal)
        .with_blocks(16, 128)
        .with_fp16_inputs();
    assert!(req.validate().is_ok());

    let fp8 = req.run();
    assert!(
        fp8.overflow_events() > 0,
        "premise: the scaled video trace must overflow the E4M3 store"
    );
    assert!(fp8.max_abs_score() > 448.0);
    assert_eq!(fp8.score_boundary, 448.0);
    // ... while the same scores sit far inside FP16.
    let fa16 = req.clone().with_alloc(Allocation::Fa16_32).run();
    assert_eq!(
        fa16.overflow_events(),
        0,
        "premise: 15%-scaled amplitudes must not trouble FP16 (peak {})",
        fa16.max_abs_score()
    );

    let pasa8 = req.clone().with_alloc(Allocation::Pasa8).run();
    assert!(!pasa8.overflowed(), "Pasa8 must stay finite on video heads");
    assert_eq!(pasa8.overflow_events(), 0, "Pasa8 pre-store events leaked");
    assert_eq!(pasa8.nonfinite_outputs(), 0);
    assert!(
        pasa8.max_abs_score() < 448.0,
        "shifted scores must fit E4M3: {}",
        pasa8.max_abs_score()
    );
    assert_eq!(pasa8.score_boundary, 448.0);
}

// ---- metamorphic invariances (PR 5 test subsystem) --------------------

/// Quantize a matrix onto the 2⁻⁶ grid, so adding 16.0 to an entry stays
/// exactly representable in FP16 (ulp at 16 is 2⁻⁶) — the shift-invariance
/// metamorphic relation needs the biased twin to hold *identical* K bits
/// plus an exact offset, or input re-rounding would contaminate the
/// comparison.
fn quantize_64th(m: &mut pasa::tensor::Matrix) {
    for x in &mut m.data {
        *x = (*x * 64.0).round() / 64.0;
    }
}

#[test]
fn metamorphic_shift_invariance_of_pasa_eq15() {
    // Softmax shift invariance (the paper's Eq. 15 exactness claim):
    // adding one shared offset vector u to every K row adds the
    // row-constant bias qᵢ·u to S, which softmax ignores exactly — and
    // which is precisely the sequence-dim bias PASA's pseudo-average
    // shift absorbs. The PASA outputs of the base and biased twins must
    // agree within fp tolerance, while the raw biased scores cross the
    // E4M3 boundary (so the invariance is doing real work for Pasa8).
    let mut rng = Pcg64::new(71, 0);
    let mut c = gen_case(Distribution::Uniform { x0: 1.0, am: 1.0 }, 96, 96, 32, &mut rng);
    quantize_64th(&mut c.q);
    quantize_64th(&mut c.k);
    quantize_64th(&mut c.v);
    let mut biased = c.clone();
    for r in 0..96 {
        for t in 0..32 {
            biased.k.set(r, t, biased.k.at(r, t) + 16.0);
        }
    }
    let base = AttentionRequest::from_case(&c, Allocation::Pasa16)
        .with_blocks(32, 32)
        .with_fp16_inputs();
    let twin = AttentionRequest::from_case(&biased, Allocation::Pasa16)
        .with_blocks(32, 32)
        .with_fp16_inputs();

    // The offset is exact in FP16 (2⁻⁶-grid inputs), so the goldens agree
    // to f32-dot-product noise — the mathematical invariance.
    let g_base = KernelRegistry::naive().forward(&base);
    let g_twin = KernelRegistry::naive().forward(&twin);
    let e = relative_rmse(&g_twin.heads[0].data, &g_base.heads[0].data);
    assert!(e < 1e-3, "golden shift invariance violated: rmse {e}");
    // Premise: the bias moved the raw scores past 448 (E4M3-relevant).
    assert!(
        g_twin.stats[0].max_abs_score > 448.0,
        "premise: biased raw scores must cross the E4M3 boundary, got {}",
        g_twin.stats[0].max_abs_score
    );

    // PASA(FP16): biased output within fp tolerance of the base output.
    let p_base = base.run();
    let p_twin = twin.run();
    assert!(!p_twin.overflowed());
    let e = relative_rmse(&p_twin.heads[0].data, &p_base.heads[0].data);
    assert!(e < 5e-2, "Pasa16 shift invariance: rmse {e}");

    // Pasa8: the biased twin would poison the plain FP8 row, but the
    // shift collapses the added bias before the E4M3 store — finite, no
    // events, and still within the (coarser) E4M3 tolerance of the base.
    let fp8_twin = twin.clone().with_alloc(Allocation::Fp8).run();
    assert!(
        fp8_twin.overflow_events() > 0,
        "premise: unshifted E4M3 must trip on the biased twin"
    );
    let p8_base = base.clone().with_alloc(Allocation::Pasa8).run();
    let p8_twin = twin.with_alloc(Allocation::Pasa8).run();
    assert!(!p8_twin.overflowed(), "Pasa8 must absorb the bias");
    assert_eq!(p8_twin.overflow_events(), 0);
    let e8 = relative_rmse(&p8_twin.heads[0].data, &p8_base.heads[0].data);
    assert!(e8 < 0.3, "Pasa8 shift invariance: rmse {e8}");
}

#[test]
fn metamorphic_head_permutation_equivariance() {
    // Permuting the heads of a request (and its per-head β table)
    // permutes the outputs bit for bit: heads are independent, and
    // PASA's (KV head, β)-keyed K' sharing must not couple them.
    let perm = [2usize, 0, 3, 1];
    let betas = [0.9375, 0.968994, 0.984497, 0.9375];
    let dist = Distribution::Uniform { x0: 1.0, am: 1.0 };
    let cases: Vec<_> = (0..4)
        .map(|h| {
            let mut rng = Pcg64::new(81 + h as u64, 0);
            gen_case(dist, 64, 64, 16, &mut rng)
        })
        .collect();
    for alloc in [Allocation::Fa16_32, Allocation::Pasa16, Allocation::Pasa8] {
        let mut req = AttentionRequest::new(alloc);
        for c in &cases {
            req = req.with_head(c.q.clone(), c.k.clone(), c.v.clone());
        }
        let req = req
            .with_mask(AttnMask::Causal)
            .with_blocks(32, 32)
            .with_policy(BetaPolicy::PerHead(betas.to_vec()))
            .with_fp16_inputs();
        let mut permuted = AttentionRequest::new(alloc);
        for &src in &perm {
            permuted = permuted.with_head(
                cases[src].q.clone(),
                cases[src].k.clone(),
                cases[src].v.clone(),
            );
        }
        let permuted = permuted
            .with_mask(AttnMask::Causal)
            .with_blocks(32, 32)
            .with_policy(BetaPolicy::PerHead(perm.iter().map(|&s| betas[s]).collect()))
            .with_fp16_inputs();
        let out = req.run();
        let out_p = permuted.run();
        let bits = |m: &pasa::tensor::Matrix| -> Vec<u32> {
            m.data.iter().map(|x| x.to_bits()).collect()
        };
        for i in 0..4 {
            assert_eq!(
                bits(&out_p.heads[i]),
                bits(&out.heads[perm[i]]),
                "{}: permuted head {i} != original head {}",
                alloc.name(),
                perm[i]
            );
            assert_eq!(
                out_p.stats[i].overflow_events,
                out.stats[perm[i]].overflow_events,
                "{}: permuted head {i} telemetry",
                alloc.name()
            );
            assert_eq!(
                out_p.stats[i].max_abs_score.to_bits(),
                out.stats[perm[i]].max_abs_score.to_bits(),
                "{}: permuted head {i} max|S|",
                alloc.name()
            );
        }
    }
}

#[test]
fn metamorphic_beta_monotonicity_on_resonance_traces() {
    // Larger β never increases the pre-store max |S'| on the resonance
    // traces: the shift removes more of the coherent bias/resonance as β
    // grows (a 5% slack absorbs rounding wiggle at the incoherent
    // floor), and the strongest paper β must cut the β = 0 peak by at
    // least half. Full-participation variant: rows far *below* the
    // average amplitude (non-participating bands) are over-shifted as
    // β → 1 — a known overshoot that is not monotone in β and exactly
    // why the paper's grid stops at 1 − 2⁻⁶ — so the monotonicity claim
    // is stated over the coherent resonance itself.
    for trace in all_traces(16) {
        let mut spec = trace.spec.clone();
        spec.s1 = 48;
        spec.s2 = 48;
        spec.participation = 1.0;
        spec.flip_fraction = 0.0;
        let c = spec.generate(5);
        let req = AttentionRequest::from_case(&c, Allocation::Pasa16)
            .with_blocks(48, 48)
            .with_fp16_inputs();
        let mut peaks = Vec::new();
        for &b in &[0.0, 0.9375, 0.968994, 0.984497] {
            let out = req.clone().with_beta(b).run();
            peaks.push(out.max_abs_score());
        }
        for w in peaks.windows(2) {
            assert!(
                w[1] <= w[0] * 1.05,
                "{}: β-monotonicity violated: peaks {peaks:?}",
                trace.name
            );
        }
        assert!(
            peaks[3] < 0.5 * peaks[0],
            "{}: the paper β must cut the unshifted peak: {peaks:?}",
            trace.name
        );
    }
}

#[test]
fn long_context_pasa_drift_stays_bounded() {
    // Long-context drift of PASA's F̄ running average (the incremental
    // Eq. 15 form): a masked request at s2 = 2560 ≫ the paper's 1280 —
    // 20 KV blocks at the default 128 tiling — charted against shorter
    // prefixes of the same data. The RMSE against the masked f32 golden
    // is pinned at every length: the running average must not drift the
    // error out of the FP16 envelope as blocks accumulate.
    let mut rng = Pcg64::new(51, 0);
    let c = gen_case(Distribution::Uniform { x0: 10.0, am: 1.0 }, 128, 2560, 64, &mut rng);
    let base = AttentionRequest::from_case(&c, Allocation::Pasa16).with_fp16_inputs();
    let mut chart = Vec::new();
    for len in [640usize, 1280, 2560] {
        let req = base.clone().with_mask(AttnMask::Padded(vec![len]));
        let golden = KernelRegistry::naive().forward(&req);
        let out = req.run();
        assert!(!out.overflowed(), "len {len}: PASA overflowed");
        assert_eq!(out.overflow_events(), 0, "len {len}: events leaked");
        let e = relative_rmse(&out.heads[0].data, &golden.heads[0].data);
        assert!(e < 3e-2, "len {len}: drift pushed rmse to {e}");
        chart.push((len, e));
    }
    // The chart exists and covers the long end; the bound above is the
    // pinned acceptance. (Drift grows with block count but must stay
    // inside the envelope — that is the regression this test guards.)
    assert_eq!(chart.last().unwrap().0, 2560);
}
