//! Property tests on the coordinator invariants (paged KV pool, router)
//! via the crate's mini property-testing harness (rust/src/testing.rs).

use pasa::coordinator::{
    Engine, EngineConfig, GenParams, GuardPolicy, KvPool, Priority, Request, Router,
    SchedulerConfig, SeqCache, StreamEvent,
};
use pasa::model::{ModelDims, Sampling};
use pasa::runtime::LabModel;
use pasa::testing::check;
use pasa::workloads::{prompt_of_tokens, Pcg64};

/// Random op sequence for the pool: (seq index, op code, argument).
fn gen_ops(rng: &mut Pcg64) -> Vec<(usize, usize, usize)> {
    let n = 2 + rng.below(40);
    (0..n)
        .map(|_| (rng.below(6), rng.below(4), rng.below(96) + 1))
        .collect()
}

#[test]
fn kv_pool_never_leaks_or_double_frees() {
    check(
        60,
        0xA11CE,
        gen_ops,
        |ops: &Vec<(usize, usize, usize)>| {
            let mut pool = KvPool::new(256, 8, 16);
            let mut seqs: Vec<SeqCache> = (0..6).map(|_| SeqCache::new(2)).collect();
            for &(si, op, arg) in ops {
                match op {
                    0 => {
                        // grow (may fail on capacity — must not corrupt)
                        let _ = seqs[si].ensure_capacity(&mut pool, arg);
                    }
                    1 => {
                        let tokens = seqs[si].len_tokens;
                        if tokens > 0 {
                            let pos = arg % tokens;
                            let row = vec![si as f32; 16];
                            // May fail under CoW exhaustion — that is
                            // backpressure, not corruption; invariants
                            // below still must hold.
                            let _ = seqs[si].write_row(&mut pool, arg % 2, pos, &row, &row);
                        }
                    }
                    2 => {
                        seqs[si].release(&mut pool);
                    }
                    _ => {
                        // fork then immediately write through the fork
                        let mut f = seqs[si].fork(&mut pool);
                        if f.len_tokens > 0 || seqs[si].total_pages_held() > 0 {
                            let _ = f.ensure_capacity(&mut pool, 4);
                            if f.total_pages_held() > 0 {
                                let row = vec![9.0f32; 16];
                                let _ = f.write_row(&mut pool, 0, 0, &row, &row);
                            }
                        }
                        f.release(&mut pool);
                    }
                }
                // Invariant: used pages == sum of pages held by live seqs.
                let held: usize = seqs.iter().map(|s| s.total_pages_held()).sum();
                if pool.used_pages() != held {
                    return Err(format!(
                        "page accounting broken: pool={} held={held}",
                        pool.used_pages()
                    ));
                }
            }
            for s in &mut seqs {
                s.release(&mut pool);
            }
            if pool.used_pages() != 0 {
                return Err(format!("leak: {} pages after release", pool.used_pages()));
            }
            Ok(())
        },
    );
}

#[test]
fn kv_pool_dense_readback_matches_writes() {
    check(
        40,
        0xB0B,
        |rng: &mut Pcg64| {
            let n = 1 + rng.below(30);
            (0..n).map(|_| (rng.below(64), rng.below(100))).collect::<Vec<(usize, usize)>>()
        },
        |writes: &Vec<(usize, usize)>| {
            let mut pool = KvPool::new(512, 8, 4);
            let mut s = SeqCache::new(1);
            let mut mirror = vec![0.0f32; 64 * 4];
            for &(pos, val) in writes {
                s.ensure_capacity(&mut pool, pos + 1).unwrap();
                let row = vec![val as f32; 4];
                s.write_row(&mut pool, 0, pos, &row, &row).unwrap();
                mirror[pos * 4..(pos + 1) * 4].copy_from_slice(&row);
            }
            let mut dense = vec![0.0f32; 64 * 4];
            s.fill_dense(&pool, 0, false, &mut dense).unwrap();
            let len = s.len_tokens;
            if dense[..len * 4] != mirror[..len * 4] {
                return Err("dense readback diverged from mirror".into());
            }
            if dense[len * 4..].iter().any(|&x| x != 0.0) {
                return Err("padding region not zeroed".into());
            }
            s.release(&mut pool);
            Ok(())
        },
    );
}

#[test]
fn router_conserves_requests_and_orders_lanes() {
    check(
        60,
        0xC0DE,
        |rng: &mut Pcg64| {
            let n = 1 + rng.below(30);
            (0..n).map(|_| rng.below(3)).collect::<Vec<usize>>()
        },
        |lanes: &Vec<usize>| {
            let mut router = Router::new(1024, 4096);
            let mut submitted = Vec::new();
            for &lane in lanes {
                let id = router.fresh_id();
                let pr = match lane {
                    0 => Priority::Batch,
                    1 => Priority::Normal,
                    _ => Priority::Interactive,
                };
                router.submit(Request::new(id, "x").with_priority(pr));
                submitted.push((pr, id));
            }
            // Drain: priorities must be non-increasing, FCFS within a lane.
            let mut drained = Vec::new();
            while let Some(r) = router.pop() {
                drained.push((r.priority, r.id));
            }
            if drained.len() != submitted.len() {
                return Err("requests lost or duplicated".into());
            }
            for w in drained.windows(2) {
                if w[1].0 > w[0].0 {
                    return Err(format!("priority inversion: {w:?}"));
                }
                if w[1].0 == w[0].0 && w[1].1 < w[0].1 {
                    return Err(format!("FCFS violated within lane: {w:?}"));
                }
            }
            Ok(())
        },
    );
}

/// Request-lifecycle property (S19): under random deadlines, retry
/// budgets, shed thresholds and cancellations, every submitted request
/// reaches exactly one terminal event (legal `Phase` transitions only —
/// a double terminal or a token after the terminal would be an illegal
/// transition observed on the wire), the engine drains in bounded
/// steps, and the KV pool returns to zero pages held.
#[test]
fn engine_lifecycle_reaches_exactly_one_terminal_per_request() {
    let lab_dims = || ModelDims {
        vocab_size: 259,
        d_model: 16,
        n_layers: 1,
        n_heads: 2,
        d_head: 8,
        d_ff: 32,
        max_seq: 48,
        prefill_seq: 16,
        decode_batch: 2,
        pad: 256,
        bos: 257,
        eos: 258,
    };
    check(
        25,
        0x11FEC,
        |rng: &mut Pcg64| (1 + rng.below(8), rng.next_u64()),
        |&(n, seed): &(usize, u64)| {
            let mut rng = Pcg64::new(seed, 7);
            let cfg = EngineConfig {
                policy: GuardPolicy::Adaptive,
                kv_pages: 32,
                page_tokens: 4,
                max_queue: 64,
                deadline_steps: rng.below(8), // 0 = engine deadline off
                sched: SchedulerConfig {
                    max_batch_prefill_tokens: 8,
                    retry_budget: rng.below(3),
                    shed_queue_depth: rng.below(4), // 0 = shedding off
                    ..SchedulerConfig::default()
                },
                ..EngineConfig::default()
            };
            let mut eng = Engine::from_lab(LabModel::synthetic(lab_dims(), 42), cfg);
            for id in 1..=n as u64 {
                let mut req = Request::new(id, prompt_of_tokens(2 + rng.below(12)))
                    .with_params(GenParams {
                        max_new_tokens: 1 + rng.below(6),
                        sampling: Sampling::Greedy,
                        stop_at_eos: false,
                    });
                if rng.below(3) == 0 {
                    req = req.with_deadline(2 + rng.below(20) as u64);
                }
                if rng.below(4) == 0 {
                    req = req.with_priority(Priority::Interactive);
                }
                eng.submit(req);
            }
            let mut events = Vec::new();
            let mut comps = 0usize;
            let mut steps = 0usize;
            while !eng.idle() {
                // Cancellation from whatever phase the victim happens to
                // be in — queued, mid-prefill, decoding, or retry-parked.
                if rng.below(4) == 0 {
                    let _ = eng.cancel(1 + rng.below(n) as u64);
                }
                eng.step().map_err(|e| format!("step failed: {e}"))?;
                events.extend(eng.take_events());
                comps += eng.take_completions().len();
                steps += 1;
                if steps > 2_000 {
                    return Err("engine failed to drain".into());
                }
            }
            let mut terminal: Vec<u64> = Vec::new();
            for e in &events {
                match e {
                    StreamEvent::Finished { request_id, .. } => {
                        if terminal.contains(request_id) {
                            return Err(format!("request {request_id} finished twice"));
                        }
                        terminal.push(*request_id);
                    }
                    StreamEvent::Token(t) => {
                        if terminal.contains(&t.request_id) {
                            return Err(format!(
                                "request {} streamed a token after its terminal event",
                                t.request_id
                            ));
                        }
                    }
                }
            }
            if terminal.len() != n {
                return Err(format!("{} terminals for {n} requests", terminal.len()));
            }
            if comps != n {
                return Err(format!("{comps} completions for {n} requests"));
            }
            if eng.kv_utilization() != 0.0 {
                return Err(format!("pages leaked: utilization {}", eng.kv_utilization()));
            }
            Ok(())
        },
    );
}

#[test]
fn kv_pool_fork_isolation_property() {
    check(
        40,
        0xF0,
        |rng: &mut Pcg64| (rng.below(32) + 1, rng.below(1000) as u64),
        |&(tokens, seed): &(usize, u64)| {
            let mut rng = Pcg64::new(seed, 1);
            let mut pool = KvPool::new(512, 8, 4);
            let mut a = SeqCache::new(1);
            a.ensure_capacity(&mut pool, tokens).unwrap();
            for p in 0..tokens {
                let row = vec![p as f32; 4];
                a.write_row(&mut pool, 0, p, &row, &row).unwrap();
            }
            let mut b = a.fork(&mut pool);
            // Random writes through the fork must never show up in `a`.
            for _ in 0..8 {
                let p = rng.below(tokens);
                let row = vec![-1.0f32; 4];
                b.write_row(&mut pool, 0, p, &row, &row).unwrap();
            }
            let mut dense = vec![0.0f32; ((tokens + 7) / 8) * 8 * 4];
            a.fill_dense(&pool, 0, false, &mut dense).unwrap();
            for p in 0..tokens {
                if dense[p * 4] != p as f32 {
                    return Err(format!("fork leaked into original at {p}"));
                }
            }
            a.release(&mut pool);
            b.release(&mut pool);
            if pool.used_pages() != 0 {
                return Err("leak after fork release".into());
            }
            Ok(())
        },
    );
}
