//! Integration tests over the attention lab + experiment harness
//! (no artifacts required — pure rust layers), through the unified
//! AttentionRequest / KernelRegistry API.

use pasa::attention::{Allocation, AttentionRequest, KernelRegistry};
use pasa::experiments::{self, ExpOptions};
use pasa::numerics::relative_rmse;
use pasa::workloads::{all_traces, gen_multihead, Distribution};

fn fast_opts() -> ExpOptions {
    ExpOptions {
        heads: 1,
        seq: 384,
        dim: 128,
        trace_scale: 16,
        seed: 9,
    }
}

#[test]
fn all_experiments_run_and_report() {
    let opts = fast_opts();
    for id in experiments::ALL_EXPERIMENTS {
        let rep = experiments::run(id, &opts).unwrap();
        assert!(rep.contains('#'), "{id} produced an empty report");
        assert!(rep.len() > 60, "{id} report suspiciously short:\n{rep}");
    }
}

#[test]
fn unknown_experiment_is_an_error() {
    assert!(experiments::run("fig99", &fast_opts()).is_err());
}

#[test]
fn paper_headline_multihead() {
    // The paper's (B, N, S, D) benchmark at reduced size: FA16-32 NaNs on
    // the x0=30 case in *every* head, PASA survives with small RMSE —
    // one request, every head through the same kernel.
    let mh = gen_multihead(Distribution::Uniform { x0: 30.0, am: 0.5 }, 2, 384, 128, 1);
    let req = AttentionRequest::from_multihead(&mh, Allocation::Fa16_32).with_fp16_inputs();
    let golden = KernelRegistry::naive().forward(&req);
    let fa = req.run();
    for h in 0..2 {
        assert!(fa.stats[h].nonfinite_outputs > 0, "head {h} did not overflow");
        assert!(fa.stats[h].overflow_events > 0, "head {h} missing telemetry");
        assert!(fa.stats[h].max_abs_score > 65504.0, "head {h} score too small");
    }
    let p = req.clone().with_alloc(Allocation::Pasa16).run();
    assert!(!p.overflowed());
    assert_eq!(p.overflow_events(), 0);
    for h in 0..2 {
        let e = relative_rmse(&p.heads[h].data, &golden.heads[h].data);
        assert!(e < 2e-2, "head {h}: rmse {e}");
    }
}

#[test]
fn model_traces_end_to_end_rescue() {
    // Figs. 11–14 end-to-end. Both traces overflow FP16 at the
    // instrumentation point (|QK^T| > 65504). Downstream severity differs
    // by sign — the paper's own mechanism analysis:
    //  * SVD (whole score rows beyond −65504): rows saturate to −inf,
    //    exp(−inf − (−inf)) = NaN ⇒ inference failure;
    //  * Qwen2 (mixed sign): negative saturation silently zeroes weights —
    //    finite but untrustworthy output.
    // PASA must keep both finite and accurate.
    for t in all_traces(16) {
        // Deterministic seeds where each trace exhibits its failure mode
        // (7: qwen2 mixed-sign overflow; 11: svd whole-row saturation).
        let seed = if t.name == "svd-img2vid" { 11 } else { 7 };
        let req =
            AttentionRequest::from_case(&t.generate(seed), Allocation::Fa16_32).with_fp16_inputs();
        let fa = req.run();
        // Kernel telemetry replaces the old raw-score probe: the pre-store
        // |S| must exceed the FP16 boundary on both traces.
        assert!(
            fa.max_abs_score() > 65504.0,
            "{}: raw scores do not overflow",
            t.name
        );
        assert!(fa.overflow_events() > 0, "{}: no overflow events", t.name);
        if t.name == "svd-img2vid" {
            assert!(fa.overflowed(), "{} should NaN FA16-32", t.name);
        }
        let p = req.clone().with_alloc(Allocation::Pasa16).run();
        assert!(!p.overflowed(), "{} overflowed PASA", t.name);
        let golden = KernelRegistry::naive().forward(&req);
        let e = relative_rmse(&p.heads[0].data, &golden.heads[0].data);
        // The qwen2-like trace keeps |scores| in the tens of thousands
        // even after the shift (paper Fig. 13: [−58134, 1124]); at those
        // magnitudes FP16 rounding can flip near-tied argmax rows, so the
        // RMSE bound is loose there — the robustness claim is finiteness.
        let bound = if t.name == "qwen2-7b" { 0.5 } else { 0.1 };
        assert!(e < bound, "{}: PASA rmse {e}", t.name);
    }
}

#[test]
fn rmse_orderings_hold_across_seeds() {
    // Fig. 9 qualitative orderings that are robust in bit-exact emulation:
    //  * FA(FP32) is far more accurate than both FP16 paths;
    //  * where FA16-32 survives, PASA is comparable (within 2.5x);
    //  * where FA16-32 overflows (x0 = 30), PASA still delivers small RMSE.
    // (The paper's "PASA strictly beats FA16-32 at non-zero mean" holds in
    // the strong-bias/overflow regime; pre-overflow they interleave —
    // recorded in EXPERIMENTS.md.)
    for seed in [11, 22, 33] {
        let opts = ExpOptions { seed, ..fast_opts() };
        let mild = Distribution::Uniform { x0: 20.0, am: 2.0 };
        let e32 = experiments::rmse_sweep::rmse_for(mild, Allocation::Fa32, &opts);
        let ep = experiments::rmse_sweep::rmse_for(mild, Allocation::Pasa16, &opts);
        let efa = experiments::rmse_sweep::rmse_for(mild, Allocation::Fa16_32, &opts);
        assert!(e32 < ep, "seed {seed}: FA32 {e32} !< PASA {ep}");
        assert!(ep < 2.5 * efa, "seed {seed}: PASA {ep} not comparable to {efa}");
        let hard = Distribution::Uniform { x0: 30.0, am: 0.5 };
        assert!(experiments::rmse_sweep::rmse_for(hard, Allocation::Fa16_32, &opts).is_nan());
        let ep = experiments::rmse_sweep::rmse_for(hard, Allocation::Pasa16, &opts);
        assert!(ep < 2e-2, "seed {seed}: PASA rmse {ep} at the overflow point");
    }
}

#[test]
fn paged_views_reproduce_the_overflow_rescue() {
    // PR 2: the paper's headline overflow/rescue behaviour must survive
    // the paged-KV path — FA16-32 over a paged view of biased data
    // overflows exactly like the dense run, and the PASA replay over the
    // *same pages* comes back clean and accurate.
    use pasa::attention::{AttnMask, KvPair, KvView};
    use pasa::coordinator::{KvPool, SeqCache};

    let mh = pasa::workloads::gen_paged_decode_case(
        Distribution::Uniform { x0: 30.0, am: 0.5 },
        2,
        1,
        192,
        256,
        128,
        77,
    );
    let mut pool = KvPool::new(128, 16, 128);
    let mut cache = SeqCache::new(1);
    cache.ensure_capacity(&mut pool, 192).unwrap();
    let (kp, vp) = mh.packed_kv_rows();
    for r in 0..192 {
        cache.write_row(&mut pool, 0, r, kp.row(r), vp.row(r)).unwrap();
    }
    let pairs = [KvPair {
        k: KvView::paged(cache.page_ids(0, false), &pool, 192),
        v: KvView::paged(cache.page_ids(0, true), &pool, 192),
    }];
    let mut req = AttentionRequest::new(Allocation::Fa16_32).with_mask(AttnMask::Padded(vec![192]));
    for q in &mh.q {
        req = req.with_query_head(q.clone());
    }
    let fa = req.run_with_kv(&pairs);
    assert!(fa.overflowed(), "premise: biased paged KV must overflow FA16-32");
    assert!(fa.overflow_events() > 0);
    // Same pages, PASA allocation: the rescue.
    let rescue = req.with_alloc(Allocation::Pasa16).run_with_kv(&pairs);
    assert!(!rescue.overflowed());
    assert_eq!(rescue.overflow_events(), 0);
    // Accuracy against the truncated dense golden reference.
    let golden = KernelRegistry::naive().forward(&AttentionRequest::from_multihead(
        &mh,
        Allocation::Fa32,
    ));
    for h in 0..2 {
        let e = relative_rmse(&rescue.heads[h].data, &golden.heads[h].data);
        assert!(e < 5e-2, "head {h}: rmse {e}");
    }
}
