//! Allocation discipline of the attention hot path (PR 4 acceptance):
//! after workspace warm-up, the flash/PASA **inner KV loops perform zero
//! heap allocations** — pinned with a counting global allocator.
//!
//! The invariant is asserted shape-relatively: with the same block sizes,
//! a forward over twice as many KV blocks must cost the *same* number of
//! allocations (flash: exactly — only the output matrix is allocated per
//! call), because every per-block buffer lives in the reused
//! [`pasa::attention::AttnWorkspace`]. PASA's preprocessing legitimately
//! keeps one K' matrix per KV block, so its count may grow by O(#blocks)
//! — but nothing per (Q-block × KV-block), which is where the old
//! implementation allocated ~15 buffers per iteration. PR 8 extends the
//! same pin to the quantized-KV decode path: a paged flash forward over a
//! byte-backed E4M3 pool (whose gather dequantizes through a LUT into the
//! workspace panel) must be equally flat in the number of KV blocks.
//!
//! This file holds a single test: the counter is process-global, so
//! concurrent tests would add noise (the min-of-repeats measurement
//! filters transient harness activity, not sustained parallel load).

use pasa::attention::{
    flash_head, flash_head_kv, pasa_head, pasa_preprocess, Allocation, AttentionConfig, HeadMask,
};
use pasa::coordinator::{KvPool, KvStore, SeqCache};
use pasa::workloads::{gen_case, AttentionCase, Distribution, Pcg64};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: every operation defers verbatim to `System`; the counter
// increment is a side effect with no bearing on allocator correctness.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: forwarded to System under the caller's own contract.
        unsafe { System.alloc(l) }
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: forwarded to System under the caller's own contract.
        unsafe { System.alloc_zeroed(l) }
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: forwarded to System under the caller's own contract.
        unsafe { System.realloc(p, l, new_size) }
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        // SAFETY: forwarded to System under the caller's own contract.
        unsafe { System.dealloc(p, l) }
    }
}

#[global_allocator]
static COUNTER: CountingAlloc = CountingAlloc;

/// Allocations of one run of `f`, minimized over repeats so one-off
/// background activity (test-harness bookkeeping) cannot inflate the
/// measurement; deterministic per-call allocations survive the min.
fn count_allocs<F: FnMut()>(mut f: F) -> u64 {
    let mut best = u64::MAX;
    for _ in 0..3 {
        let before = ALLOCS.load(Ordering::SeqCst);
        f();
        best = best.min(ALLOCS.load(Ordering::SeqCst) - before);
    }
    best
}

fn rounded_case(s1: usize, s2: usize, d: usize, seed: u64) -> AttentionCase {
    let mut rng = Pcg64::new(seed, 0);
    let mut c = gen_case(Distribution::Uniform { x0: 2.0, am: 1.0 }, s1, s2, d, &mut rng);
    c.q.round_to(pasa::numerics::Format::F16);
    c.k.round_to(pasa::numerics::Format::F16);
    c.v.round_to(pasa::numerics::Format::F16);
    c
}

#[test]
fn inner_kv_loops_allocate_nothing_after_warmup() {
    // Keep everything on this thread so the global counter sees only this
    // test's allocations (the guard is a formality here — this binary
    // holds a single test — but keeps the toggling discipline uniform).
    let _mode = pasa::pool::test_mode_guard();
    pasa::pool::set_parallel(false);

    let d = 64usize;
    let s1 = 128usize;
    let cfg = AttentionConfig::new(Allocation::Fa16_32).with_blocks(64, 64);
    let short = rounded_case(s1, 640, d, 1); // 10 KV blocks
    let long = rounded_case(s1, 1280, d, 2); // 20 KV blocks

    // Warm-up: grows the thread workspace to its steady-state shape.
    std::hint::black_box(flash_head(&long.q, &long.k, &long.v, HeadMask::Causal, &cfg));
    std::hint::black_box(flash_head(&short.q, &short.k, &short.v, HeadMask::Causal, &cfg));

    // Flash: the only per-call allocation is the output matrix, so the
    // count must be identical at 10 and at 20 KV blocks — the inner loop
    // contributes zero.
    let flash_short = count_allocs(|| {
        std::hint::black_box(flash_head(&short.q, &short.k, &short.v, HeadMask::Causal, &cfg));
    });
    let flash_long = count_allocs(|| {
        std::hint::black_box(flash_head(&long.q, &long.k, &long.v, HeadMask::Causal, &cfg));
    });
    assert_eq!(
        flash_short, flash_long,
        "flash allocation count scales with KV blocks: {flash_short} at 10 blocks \
         vs {flash_long} at 20 — the inner KV loop is allocating"
    );
    assert!(
        flash_long <= 4,
        "flash forward allocated {flash_long} times; expected ~1 (the output matrix)"
    );

    // PASA: preprocessing owns one K' block matrix per KV block (plus the
    // shifting matrix and Vec growth), so the count may grow linearly in
    // blocks — but the Q-sweep itself must contribute zero. 10 extra KV
    // blocks may add at most ~2 allocations each (gathered K' + table
    // growth); the old kernel allocated ~15 per (Q-block × KV-block),
    // i.e. 300+ extra here.
    let pcfg = AttentionConfig::new(Allocation::Pasa16).with_blocks(64, 64);
    let run_pasa = |c: &AttentionCase| {
        let pre = pasa_preprocess(&c.k, &pcfg);
        std::hint::black_box(pasa_head(&c.q, &c.v, &pre, HeadMask::Causal, &pcfg));
    };
    run_pasa(&long);
    run_pasa(&short);
    let pasa_short = count_allocs(|| run_pasa(&short));
    let pasa_long = count_allocs(|| run_pasa(&long));
    let extra_blocks = 10u64;
    assert!(
        pasa_long.saturating_sub(pasa_short) <= 3 * extra_blocks,
        "PASA allocations grew by {} for {extra_blocks} extra KV blocks — \
         more than preprocessing alone can explain",
        pasa_long.saturating_sub(pasa_short)
    );
    assert!(
        pasa_long <= 3 * 20 + 16,
        "PASA forward allocated {pasa_long} times at 20 KV blocks; \
         expected ≈ one K' matrix per block plus constants"
    );

    // Quantized-KV decode path (PR 8): the paged gather out of a
    // byte-backed E4M3 pool dequantizes through a 256-entry LUT straight
    // into the workspace panel — no intermediate f32 page, no heap. Same
    // shape-relative pin as dense flash: the forward over 20 E4M3 KV
    // blocks must cost exactly as many allocations as over 10.
    let mut pool = KvPool::new_with_store(96, 64, d, KvStore::E4m3);
    let mut fill_cache = |c: &AttentionCase, pool: &mut KvPool| {
        let mut s = SeqCache::new(1);
        s.ensure_capacity(pool, c.k.rows).unwrap();
        for pos in 0..c.k.rows {
            s.write_row(pool, 0, pos, c.k.row(pos), c.v.row(pos)).unwrap();
        }
        s
    };
    let cache_short = fill_cache(&short, &mut pool);
    let cache_long = fill_cache(&long, &mut pool);
    let run_paged = |c: &AttentionCase, s: &SeqCache| {
        let (kv, vv) = s.kv_views(&pool, 0);
        std::hint::black_box(flash_head_kv(&c.q, kv, vv, HeadMask::Causal, &cfg));
    };
    // Warm-up to the 20-block steady-state panel shape, then measure.
    run_paged(&long, &cache_long);
    run_paged(&short, &cache_short);
    let paged_short = count_allocs(|| run_paged(&short, &cache_short));
    let paged_long = count_allocs(|| run_paged(&long, &cache_long));
    assert_eq!(
        paged_short, paged_long,
        "E4M3 paged-KV allocation count scales with KV blocks: {paged_short} at \
         10 blocks vs {paged_long} at 20 — the dequantizing gather is allocating"
    );
    assert!(
        paged_long <= 4,
        "E4M3 paged flash forward allocated {paged_long} times; expected ~1 \
         (the output matrix)"
    );

    pasa::pool::set_parallel(true);
}
