//! Chaos soak + request-lifecycle hardening integration tests (S19).
//!
//! The harness under test: a seeded [`FaultPlan`] injecting faults at
//! the engine's real seams (KV rows, the backend step, the pool free
//! list, the admission gate), and the lifecycle machinery that has to
//! absorb them — step-denominated deadlines, client cancellation,
//! retry-with-backoff for evictions, queue-depth load shedding, and the
//! non-finite-logit watchdog that quarantines a faulted slot.
//!
//! Invariants pinned here, under fault storms:
//! * no panics — every seeded run drains;
//! * every admitted request terminates with **exactly one**
//!   `StreamEvent::Finished`, and emits no tokens after it;
//! * token conservation — `metrics.tokens_generated` equals the token
//!   events on the wire, and a completion's tokens are exactly its last
//!   streamed attempt;
//! * the KV pool drains to zero utilization (no leaked refcounts);
//! * the `Metrics` robustness counters reconcile one-for-one against
//!   the plan's injection log;
//! * the same seed replays the same run — token streams and injection
//!   log alike;
//! * a quarantined slot leaves its co-batched neighbours' token streams
//!   **bit-identical** to a fault-free run.

use pasa::coordinator::{
    Admission, Completion, Engine, EngineConfig, FaultKind, FaultPlan, FaultRates, FinishReason,
    GenParams, GuardPolicy, KvStore, Priority, Request, SchedulerConfig, ScriptedFault,
    StreamEvent,
};
use pasa::model::{ModelDims, Sampling};
use pasa::runtime::LabModel;
use pasa::workloads::{prompt_of_tokens, shared_prefix_prompt, Pcg64};

fn dims(n_layers: usize, max_seq: usize, decode_batch: usize) -> ModelDims {
    ModelDims {
        vocab_size: 259,
        d_model: 16,
        n_layers,
        n_heads: 2,
        d_head: 8,
        d_ff: 32,
        max_seq,
        prefill_seq: 16,
        decode_batch,
        pad: 256,
        bos: 257,
        eos: 258,
    }
}

fn params(max_new_tokens: usize, sampling: Sampling) -> GenParams {
    GenParams {
        max_new_tokens,
        sampling,
        stop_at_eos: false,
    }
}

/// Drive an engine over `(step, request)` arrivals and `(step, id)`
/// cancellations until idle. Returns (completions, events, cancels that
/// landed) in emission order.
fn drive(
    eng: &mut Engine<'_>,
    arrivals: &[(u64, Request)],
    cancels: &[(u64, u64)],
) -> (Vec<Completion>, Vec<StreamEvent>, u64) {
    let mut comps = Vec::new();
    let mut events = Vec::new();
    let mut landed = 0u64;
    let mut next = 0usize;
    let mut step = 0u64;
    while next < arrivals.len() || !eng.idle() {
        while next < arrivals.len() && arrivals[next].0 <= step {
            assert_eq!(
                eng.submit(arrivals[next].1.clone()),
                Admission::Queued,
                "trace request must admit"
            );
            next += 1;
        }
        for &(when, id) in cancels {
            if when == step && eng.cancel(id) {
                landed += 1;
            }
        }
        eng.step().unwrap();
        comps.extend(eng.take_completions());
        events.extend(eng.take_events());
        step += 1;
        assert!(step < 20_000, "engine failed to drain under chaos");
    }
    (comps, events, landed)
}

/// A request's streamed token attempts: each retry restarts the token
/// index at 0, opening a new segment.
fn segments(events: &[StreamEvent], id: u64) -> Vec<Vec<u32>> {
    let mut segs: Vec<Vec<u32>> = Vec::new();
    for e in events {
        let StreamEvent::Token(t) = e else { continue };
        if t.request_id != id {
            continue;
        }
        if t.index == 0 {
            segs.push(Vec::new());
        }
        let seg = segs.last_mut().expect("first streamed token of an attempt must have index 0");
        assert_eq!(t.index, seg.len(), "token indices must be gapless");
        seg.push(t.token);
    }
    segs
}

/// The finish reasons streamed for `id`, and the invariant that no
/// token follows the terminal marker.
fn finish_reasons(events: &[StreamEvent], id: u64) -> Vec<FinishReason> {
    let mut reasons = Vec::new();
    for e in events {
        match e {
            StreamEvent::Finished { request_id, reason } if *request_id == id => {
                reasons.push(*reason)
            }
            StreamEvent::Token(t) if t.request_id == id => assert!(
                reasons.is_empty(),
                "request {id} streamed a token after its terminal event"
            ),
            StreamEvent::Token(_) | StreamEvent::Finished { .. } => {}
        }
    }
    reasons
}

fn greedy(id: u64, prompt_tokens: usize, max_new: usize) -> Request {
    Request::new(id, prompt_of_tokens(prompt_tokens)).with_params(params(max_new, Sampling::Greedy))
}

// ---------------------------------------------------------------------------
// Chaos soak
// ---------------------------------------------------------------------------

struct SoakRun {
    comps: Vec<Completion>,
    events: Vec<StreamEvent>,
    cancels_landed: u64,
    n_requests: u64,
}

fn run_soak(seed: u64, store: KvStore) -> (Engine<'static>, SoakRun) {
    run_soak_with(seed, store, 0)
}

/// The soak body; `prefix_cache_pages > 0` turns on the radix prefix
/// cache and switches the trace to shared-prefix prompts (a 16-token
/// common span — 4 pages at page_tokens = 4 — with per-request tails),
/// so page sharing, CoW forks, and cache eviction relief are all live
/// under the same 5% fault storm.
fn run_soak_with(
    seed: u64,
    store: KvStore,
    prefix_cache_pages: usize,
) -> (Engine<'static>, SoakRun) {
    const SHARED: usize = 16;
    let cfg = EngineConfig {
        policy: GuardPolicy::Adaptive,
        kv_pages: 64,
        page_tokens: 4,
        kv_store: store,
        max_queue: 64,
        prefix_cache_pages,
        sched: SchedulerConfig {
            max_batch_prefill_tokens: 16,
            max_batch_total_tokens: 150,
            retry_budget: 2,
            shed_queue_depth: 6,
            ..SchedulerConfig::default()
        },
        ..EngineConfig::default()
    };
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(1, 64, 3), 42), cfg);
    eng.install_faults(FaultPlan::new(seed, FaultRates::uniform(0.05)));

    // Seeded trace: staggered arrivals, mixed sampling/priority/deadline.
    // All decisions come from `seed`, so a run is a pure function of it.
    let n = 24u64;
    let mut rng = Pcg64::new(seed, 0x50AC);
    let mut at = 0u64;
    let arrivals: Vec<(u64, Request)> = (1..=n)
        .map(|id| {
            at += rng.below(3) as u64;
            let sampling = match rng.below(3) {
                0 => Sampling::Greedy,
                1 => Sampling::Temperature(0.9),
                _ => Sampling::TopK { k: 8, temperature: 0.8 },
            };
            let prompt = if prefix_cache_pages > 0 {
                shared_prefix_prompt(SHARED, SHARED + 2 + rng.below(12), id as usize)
            } else {
                prompt_of_tokens(2 + rng.below(22))
            };
            let mut req = Request::new(id, prompt)
                .with_params(params(2 + rng.below(9), sampling));
            if rng.below(4) == 0 {
                req = req.with_deadline(40 + rng.below(40) as u64);
            }
            req = match rng.below(5) {
                0 => req.with_priority(Priority::Interactive),
                1 => req.with_priority(Priority::Batch),
                _ => req,
            };
            (at, req)
        })
        .collect();
    let cancels: Vec<(u64, u64)> = (0..4)
        .map(|_| (3 + rng.below(30) as u64, 1 + rng.below(n as usize) as u64))
        .collect();

    let (comps, events, cancels_landed) = drive(&mut eng, &arrivals, &cancels);
    (eng, SoakRun { comps, events, cancels_landed, n_requests: n })
}

fn assert_soak_invariants(eng: &Engine<'_>, run: &SoakRun) {
    let n = run.n_requests;
    assert_eq!(run.comps.len() as u64, n, "every admitted request completes once");
    assert!(eng.idle());
    assert_eq!(eng.kv_utilization(), 0.0, "pages leaked under chaos");

    for id in 1..=n {
        let reasons = finish_reasons(&run.events, id);
        assert_eq!(reasons.len(), 1, "request {id}: exactly one terminal event");
        let comp: Vec<&Completion> = run.comps.iter().filter(|c| c.id == id).collect();
        assert_eq!(comp.len(), 1, "request {id}: exactly one completion");
        let comp = comp[0];
        assert_eq!(comp.reason, reasons[0], "stream and completion must agree");
        let segs = segments(&run.events, id);
        if comp.tokens.is_empty() {
            // Terminated without a served attempt (shed, cancelled while
            // queued, deadline in queue, retry-exhausted eviction, ...).
        } else {
            let last = segs.last().expect("a completion with tokens was streamed");
            assert_eq!(
                &comp.tokens, last,
                "request {id}: completion tokens must be its last streamed attempt"
            );
        }
    }

    // Token conservation: the wire and the counter agree.
    let on_wire = run
        .events
        .iter()
        .filter(|e| matches!(e, StreamEvent::Token(_)))
        .count() as u64;
    assert_eq!(eng.metrics.tokens_generated, on_wire);
    assert_eq!(eng.metrics.requests_completed, n);

    // The robustness counters reconcile one-for-one with the plan's log.
    let plan = eng.fault_plan().expect("soak runs with a plan installed");
    assert!(!plan.log().is_empty(), "the soak must actually inject faults");
    assert_eq!(
        eng.metrics.robustness.faults_by_kind,
        plan.counts(),
        "metrics counters must sum to the injection log"
    );
    assert_eq!(eng.metrics.robustness.cancellations, run.cancels_landed);
}

#[test]
fn chaos_soak_holds_lifecycle_invariants_across_seeds_and_stores() {
    for store in [KvStore::F32, KvStore::E4m3] {
        for seed in [0xC0FFEEu64, 0xBADC0DE, 0x5EED1] {
            let (eng, run) = run_soak(seed, store);
            assert_soak_invariants(&eng, &run);
        }
    }
}

#[test]
fn chaos_soak_with_shared_prefix_cache_drains_to_zero() {
    // The shared-prefix cell: prefix cache on, every prompt sharing a
    // 16-token span, 5% uniform fault rates — sharing must survive pool
    // seizures, evictions and retries, and a post-drain flush must
    // return the pool to exactly zero pages (no leaked refcounts on
    // either side of the radix tree).
    for store in [KvStore::F32, KvStore::E4m3] {
        for seed in [0xC0FFEEu64, 0x5EED1] {
            let (mut eng, run) = run_soak_with(seed, store, 32);
            assert!(
                eng.metrics.prefix.hits > 0,
                "the shared-prefix cell never hit the cache (seed {seed:#x})"
            );
            assert_eq!(
                run.comps.len() as u64,
                run.n_requests,
                "every request completes under chaos with sharing on"
            );
            // The cache legitimately holds the hot prefix at idle;
            // flushing it must drain the pool to zero utilization.
            eng.flush_prefix_cache();
            assert_soak_invariants(&eng, &run);
        }
    }
}

#[test]
fn shared_prefix_chaos_replays_bit_identically_from_its_seed() {
    // Determinism survives the prefix cache: its LRU clock is a step
    // counter, not wall time, so the same seed must replay the same
    // tokens, outcomes, injections — and the same hit/eviction counts.
    let (mut a, run_a) = run_soak_with(0xC0FFEE, KvStore::F32, 32);
    let (mut b, run_b) = run_soak_with(0xC0FFEE, KvStore::F32, 32);
    let tokens = |run: &SoakRun| -> Vec<(u64, usize, u32)> {
        run.events
            .iter()
            .filter_map(|e| match e {
                StreamEvent::Token(t) => Some((t.request_id, t.index, t.token)),
                StreamEvent::Finished { .. } => None,
            })
            .collect()
    };
    assert_eq!(tokens(&run_a), tokens(&run_b));
    assert_eq!(a.metrics.prefix.hits, b.metrics.prefix.hits);
    assert_eq!(a.metrics.prefix.tokens_saved, b.metrics.prefix.tokens_saved);
    assert_eq!(a.metrics.prefix.evictions, b.metrics.prefix.evictions);
    a.flush_prefix_cache();
    b.flush_prefix_cache();
    assert_eq!(a.kv_utilization(), 0.0);
    assert_eq!(b.kv_utilization(), 0.0);
}

#[test]
fn chaos_soak_replays_bit_identically_from_its_seed() {
    let fingerprint = |run: &SoakRun, eng: &Engine<'_>| {
        let tokens: Vec<(u64, usize, u32)> = run
            .events
            .iter()
            .filter_map(|e| match e {
                StreamEvent::Token(t) => Some((t.request_id, t.index, t.token)),
                StreamEvent::Finished { .. } => None,
            })
            .collect();
        let reasons: Vec<(u64, FinishReason)> =
            run.comps.iter().map(|c| (c.id, c.reason)).collect();
        let log = eng.fault_plan().unwrap().log().to_vec();
        (tokens, reasons, log)
    };
    let (eng_a, run_a) = run_soak(0xC0FFEE, KvStore::F32);
    let (eng_b, run_b) = run_soak(0xC0FFEE, KvStore::F32);
    assert_eq!(
        fingerprint(&run_a, &eng_a),
        fingerprint(&run_b, &eng_b),
        "same seed must replay the same tokens, outcomes, and injections"
    );
}

// ---------------------------------------------------------------------------
// Scripted single-fault scenarios
// ---------------------------------------------------------------------------

#[test]
fn pool_seizure_evicts_mid_decode_and_the_retry_budget_completes_it() {
    // 8-page pool, 4-token pages, 1 layer: a 6-token prompt + 8 new
    // tokens commits 14 tokens = 8 pages (K+V). Prefill occupies 4;
    // a scripted seizure at step 1 grabs the free 4, so the decode that
    // needs a fresh page at position 8 hits genuine pool exhaustion and
    // evicts. With retry_budget = 1 the engine re-enqueues it (backoff
    // 2 steps), the seizure releases, and the retry runs to completion.
    let cfg = EngineConfig {
        policy: GuardPolicy::Adaptive,
        kv_pages: 8,
        page_tokens: 4,
        max_queue: 16,
        sched: SchedulerConfig {
            retry_budget: 1,
            ..SchedulerConfig::fifo_compat()
        },
        ..EngineConfig::default()
    };
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(1, 64, 2), 42), cfg);
    let mut plan = FaultPlan::scripted(vec![ScriptedFault::new(FaultKind::PoolSeize, 0, 1)]);
    plan.seize_pages = 64; // grab everything free
    plan.seize_hold_steps = 2;
    eng.install_faults(plan);

    let (comps, events, _) = drive(&mut eng, &[(0, greedy(1, 6, 8))], &[]);

    assert_eq!(comps.len(), 1);
    assert_eq!(comps[0].reason, FinishReason::MaxTokens, "the retry must finish the request");
    assert_eq!(finish_reasons(&events, 1).len(), 1, "eviction + retry is one stream");
    assert_eq!(eng.metrics.robustness.retries, 1);
    assert_eq!(eng.metrics.deferrals.retry_backoff, 1);

    // Two streamed attempts: a truncated first, a complete second — and
    // the retry replays the first attempt's tokens exactly (same prompt,
    // same per-request RNG).
    let segs = segments(&events, 1);
    assert_eq!(segs.len(), 2, "expected eviction then retry, got {segs:?}");
    assert!(!segs[0].is_empty() && segs[0].len() < 8, "first attempt must truncate");
    assert_eq!(segs[1].len(), 8);
    assert_eq!(segs[0][..], segs[1][..segs[0].len()], "retry must replay the prefix");
    assert_eq!(comps[0].tokens, segs[1]);

    let counts = eng.fault_plan().unwrap().counts();
    assert_eq!(counts[FaultKind::PoolSeize.index()], 1);
    assert_eq!(counts.iter().sum::<u64>(), 1, "a scripted plan fires nothing else");
    assert_eq!(eng.kv_utilization(), 0.0);
}

#[test]
fn quarantined_slot_leaves_cobatched_neighbour_bit_identical() {
    // Request 1 takes a scripted non-finite logit row at its third
    // generated token and must be quarantined; request 2, co-batched
    // the whole time, must stream the exact tokens it streams in a
    // fault-free engine of its own.
    let cfg = || EngineConfig {
        policy: GuardPolicy::Adaptive,
        kv_pages: 256,
        page_tokens: 8,
        max_queue: 16,
        ..EngineConfig::default()
    };
    let victim = || greedy(1, 5, 10);
    let neighbour = || {
        Request::new(2, prompt_of_tokens(7)).with_params(params(10, Sampling::Temperature(0.8)))
    };

    let mut chaotic = Engine::from_lab(LabModel::synthetic(dims(2, 64, 2), 42), cfg());
    chaotic.install_faults(FaultPlan::scripted(vec![ScriptedFault::new(
        FaultKind::LogitNan,
        1,
        3,
    )]));
    let (comps, events, _) = drive(&mut chaotic, &[(0, victim()), (0, neighbour())], &[]);

    let by_id = |id: u64| comps.iter().find(|c| c.id == id).unwrap();
    assert_eq!(by_id(1).reason, FinishReason::Faulted);
    assert_eq!(by_id(1).tokens.len(), 3, "quarantine fires before the 4th sample");
    assert_eq!(by_id(2).reason, FinishReason::MaxTokens);
    assert_eq!(chaotic.metrics.robustness.quarantines, 1);

    // The neighbour, solo in a fault-free engine: bit-identical stream.
    let mut clean = Engine::from_lab(LabModel::synthetic(dims(2, 64, 2), 42), cfg());
    let (_, clean_events, _) = drive(&mut clean, &[(0, neighbour())], &[]);
    assert_eq!(
        segments(&events, 2),
        segments(&clean_events, 2),
        "a quarantined co-batch slot must not perturb its neighbour"
    );

    // And the victim's streamed prefix matches what it produces without
    // the fault — quarantine truncates, never corrupts.
    let mut solo = Engine::from_lab(LabModel::synthetic(dims(2, 64, 2), 42), cfg());
    let (_, solo_events, _) = drive(&mut solo, &[(0, victim())], &[]);
    let full = &segments(&solo_events, 1)[0];
    assert_eq!(by_id(1).tokens[..], full[..3]);
}

// ---------------------------------------------------------------------------
// Deadlines, shedding, cancellation
// ---------------------------------------------------------------------------

#[test]
fn engine_deadline_kills_decoding_requests_and_per_request_override_wins() {
    let cfg = EngineConfig {
        policy: GuardPolicy::Adaptive,
        kv_pages: 64,
        page_tokens: 4,
        max_queue: 16,
        deadline_steps: 4,
        ..EngineConfig::default()
    };
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(1, 64, 2), 42), cfg);
    // Request 1 inherits the engine-wide 4-step deadline and cannot
    // finish 30 tokens in time; request 2 overrides it with a roomy
    // per-request deadline and must complete.
    let arrivals = [(0, greedy(1, 4, 30)), (0, greedy(2, 4, 6).with_deadline(1000))];
    let (comps, events, _) = drive(&mut eng, &arrivals, &[]);

    let by_id = |id: u64| comps.iter().find(|c| c.id == id).unwrap();
    assert_eq!(by_id(1).reason, FinishReason::DeadlineExceeded);
    let got = by_id(1).tokens.len();
    assert!(got >= 1 && got < 30, "killed mid-decode, got {got} tokens");
    assert_eq!(by_id(2).reason, FinishReason::MaxTokens);
    assert_eq!(by_id(2).tokens.len(), 6);
    assert_eq!(finish_reasons(&events, 1).len(), 1);
    assert_eq!(eng.metrics.robustness.deadline_kills, 1);
    assert_eq!(eng.kv_utilization(), 0.0);
}

#[test]
fn deadline_expires_requests_still_waiting_in_the_queue() {
    let cfg = EngineConfig {
        policy: GuardPolicy::Adaptive,
        kv_pages: 64,
        page_tokens: 4,
        max_queue: 16,
        sched: SchedulerConfig {
            max_batch_size: 1, // one slot: the second request waits
            ..SchedulerConfig::default()
        },
        ..EngineConfig::default()
    };
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(1, 64, 1), 42), cfg);
    let arrivals = [(0, greedy(1, 4, 40)), (0, greedy(2, 4, 4).with_deadline(3))];
    let (comps, _, _) = drive(&mut eng, &arrivals, &[]);

    let by_id = |id: u64| comps.iter().find(|c| c.id == id).unwrap();
    assert_eq!(by_id(2).reason, FinishReason::DeadlineExceeded);
    assert!(by_id(2).tokens.is_empty(), "never admitted: no tokens");
    assert_eq!(by_id(1).reason, FinishReason::MaxTokens, "the running request is untouched");
    assert_eq!(eng.metrics.robustness.deadline_kills, 1);
}

#[test]
fn queue_overflow_sheds_newest_lowest_priority_first() {
    let cfg = EngineConfig {
        policy: GuardPolicy::Adaptive,
        kv_pages: 64,
        page_tokens: 4,
        max_queue: 64,
        sched: SchedulerConfig {
            max_batch_size: 1,
            shed_queue_depth: 2,
            ..SchedulerConfig::default()
        },
        ..EngineConfig::default()
    };
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(1, 64, 1), 42), cfg);
    // Five arrivals into a depth-2 queue: the three newest *Normal*
    // requests shed; the interactive request survives the sweep even
    // though it arrived last.
    let arrivals = [
        (0, greedy(1, 4, 4)),
        (0, greedy(2, 4, 4)),
        (0, greedy(3, 4, 4)),
        (0, greedy(4, 4, 4)),
        (0, greedy(5, 4, 4).with_priority(Priority::Interactive)),
    ];
    let (comps, _, _) = drive(&mut eng, &arrivals, &[]);

    let reason = |id: u64| comps.iter().find(|c| c.id == id).unwrap().reason;
    for id in [2, 3, 4] {
        assert_eq!(reason(id), FinishReason::Shed, "request {id}");
        assert!(comps.iter().find(|c| c.id == id).unwrap().tokens.is_empty());
    }
    for id in [1, 5] {
        assert_eq!(reason(id), FinishReason::MaxTokens, "request {id}");
    }
    assert_eq!(eng.metrics.robustness.sheds, 3);
}

#[test]
fn cancel_closes_the_stream_from_every_phase() {
    let cfg = EngineConfig {
        policy: GuardPolicy::Adaptive,
        kv_pages: 64,
        page_tokens: 8,
        max_queue: 16,
        sched: SchedulerConfig {
            max_batch_prefill_tokens: 8, // force the 40-token prompt to chunk
            ..SchedulerConfig::default()
        },
        ..EngineConfig::default()
    };
    let mut eng = Engine::from_lab(LabModel::synthetic(dims(1, 64, 2), 42), cfg);

    // Phase: Queued. Cancelled before the first step ever admits it.
    assert_eq!(eng.submit(greedy(1, 4, 4)), Admission::Queued);
    assert_eq!(eng.submit(greedy(2, 40, 4)), Admission::Queued);
    assert_eq!(eng.submit(greedy(3, 4, 6)), Admission::Queued);
    assert!(eng.cancel(1), "queued request must cancel");
    assert!(!eng.cancel(999), "unknown id");

    // Phase: Prefilling. One step admits request 2 and prefills 8 of
    // its 40 prompt tokens (the whole budget), leaving request 3 queued.
    eng.step().unwrap();
    assert!(eng.cancel(2), "mid-chunk prefill must cancel");
    assert_eq!(eng.kv_utilization(), 0.0, "cancelled prefill must release its pages");

    // Request 3 now runs to completion untouched.
    while !eng.idle() {
        eng.step().unwrap();
    }

    // Phase: Decoding. A fresh request, two steps in (prefill + decode),
    // is mid-generation when cancelled.
    assert_eq!(eng.submit(greedy(4, 4, 30)), Admission::Queued);
    eng.step().unwrap();
    eng.step().unwrap();
    assert!(eng.cancel(4), "decoding request must cancel");
    assert!(!eng.cancel(4), "double-cancel is a no-op");
    while !eng.idle() {
        eng.step().unwrap();
    }

    let comps = eng.take_completions();
    let events = eng.take_events();
    let by_id = |id: u64| comps.iter().find(|c| c.id == id).unwrap();
    for id in [1, 2, 4] {
        assert_eq!(by_id(id).reason, FinishReason::Cancelled, "request {id}");
        assert_eq!(finish_reasons(&events, id).len(), 1, "request {id}");
    }
    assert!(by_id(1).tokens.is_empty());
    assert!(by_id(2).tokens.is_empty(), "cancelled during prefill: nothing sampled");
    assert!(!by_id(4).tokens.is_empty(), "cancelled mid-decode: partial stream kept");
    assert_eq!(by_id(3).reason, FinishReason::MaxTokens);
    assert_eq!(by_id(3).tokens.len(), 6);
    assert_eq!(eng.metrics.robustness.cancellations, 3);
    assert_eq!(eng.kv_utilization(), 0.0);
    assert!(!eng.cancel(3), "finished request cannot cancel");
}
