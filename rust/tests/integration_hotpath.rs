//! Hot-path bit-identity goldens for the zero-allocation / worker-pool
//! refactor (PR 4 tentpole).
//!
//! One FNV-1a checksum is computed over every head's output bits plus the
//! per-head telemetry, for each (allocation × mask) combination of a GQA
//! request, across four execution variants that must all be
//! **bit-identical**:
//!
//! 1. pooled (work-stealing (head × Q-block) tiles — the default),
//! 2. sequential (the in-order fallback via `pool::set_parallel(false)`),
//! 3. a repeated pooled run (warm, dirty workspace buffers),
//! 4. paged K/V views (NaN-poisoned page tails) through `run_with_kv`.
//!
//! Any divergence — a fused op rounding differently, a workspace buffer
//! leaking state, a tile writing a wrong row, a paged gather touching a
//! stale tail — changes the checksum of exactly one variant and fails the
//! cross-pin.

use pasa::attention::{
    Allocation, AttentionOutput, AttentionRequest, AttnMask, KvPair, KvView, PageId,
};
use pasa::pool;
use pasa::tensor::Matrix;
use pasa::testkit::{matrix_bits, paged_fixture, FixturePool};
use pasa::workloads::{gen_gqa_multihead, Distribution};

/// Page size chosen to not divide the KV length, so every block gather
/// straddles page boundaries (the NaN-tail-poisoned fixture itself is
/// the shared `pasa::testkit::paged_fixture`).
const PAGE_TOKENS: usize = 24;

fn fnv_bytes(h: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *h ^= b as u64;
        *h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
}

/// FNV-1a over output bits + telemetry of a forward pass.
fn checksum(out: &AttentionOutput) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for m in &out.heads {
        for x in &m.data {
            fnv_bytes(&mut h, &x.to_bits().to_le_bytes());
        }
    }
    for s in &out.stats {
        fnv_bytes(&mut h, &s.max_abs_score.to_bits().to_le_bytes());
        fnv_bytes(&mut h, &(s.overflow_events as u64).to_le_bytes());
        fnv_bytes(&mut h, &(s.nonfinite_outputs as u64).to_le_bytes());
    }
    fnv_bytes(&mut h, &out.score_boundary.to_bits().to_le_bytes());
    h
}

/// Bit-pattern view of one head's output — NaN-safe equality
/// (overflow-poisoned FP8/Pasa8 rows are NaN by design, and `f32`
/// equality would treat identical NaNs as different).
fn head_bits(m: &Matrix) -> Vec<u32> {
    matrix_bits(m)
}

#[test]
fn all_execution_paths_share_one_checksum_per_combination() {
    const HEADS: usize = 8;
    const KV_HEADS: usize = 2;
    const S: usize = 96; // 3 Q-blocks of 32; 24-token pages straddle KV blocks
    const D: usize = 16;
    let dist = Distribution::Uniform { x0: 5.0, am: 1.0 };
    let mh = gen_gqa_multihead(dist, HEADS, KV_HEADS, S, S, D, 42);
    let base = AttentionRequest::from_multihead(&mh, Allocation::Fa32)
        .with_blocks(32, 32)
        .with_fp16_inputs();

    // Paged fixtures over the request's own (rounded) K/V heads.
    type Fixture = (FixturePool, Vec<PageId>);
    let fixtures: Vec<(Fixture, Fixture)> = (0..KV_HEADS)
        .map(|kvh| {
            (
                paged_fixture(&base.k[kvh], PAGE_TOKENS),
                paged_fixture(&base.v[kvh], PAGE_TOKENS),
            )
        })
        .collect();

    let masks = [
        AttnMask::None,
        AttnMask::Causal,
        AttnMask::Padded(vec![72]), // broadcast, not page- or block-aligned
    ];
    // The parallel/sequential toggle is process-global: serialize with
    // every other test that flips it so the baselines mean what they say.
    let _mode = pool::test_mode_guard();
    for alloc in Allocation::all_extended() {
        for mask in &masks {
            let req = base.clone().with_alloc(alloc).with_mask(mask.clone());
            let label = format!("{} mask={}", alloc.name(), mask.label());

            let pooled = req.run();
            let c_pooled = checksum(&pooled);

            pool::set_parallel(false);
            let sequential = req.run();
            pool::set_parallel(true);
            assert_eq!(
                c_pooled,
                checksum(&sequential),
                "pooled vs sequential fan-out diverged: {label}"
            );

            let rerun = req.run();
            assert_eq!(
                c_pooled,
                checksum(&rerun),
                "workspace reuse (warm rerun) diverged: {label}"
            );

            let pairs: Vec<KvPair<'_>> = fixtures
                .iter()
                .map(|((kp, kids), (vp, vids))| KvPair {
                    k: KvView::paged(kids, kp, S),
                    v: KvView::paged(vids, vp, S),
                })
                .collect();
            let paged = req.run_with_kv(&pairs);
            assert_eq!(
                c_pooled,
                checksum(&paged),
                "paged KV views diverged from dense: {label}"
            );

            // Head-level bit equality too, so a failure localizes.
            for h in 0..HEADS {
                assert_eq!(
                    head_bits(&pooled.heads[h]),
                    head_bits(&sequential.heads[h]),
                    "{label}: head {h} pooled vs sequential"
                );
                assert_eq!(
                    head_bits(&pooled.heads[h]),
                    head_bits(&paged.heads[h]),
                    "{label}: head {h} dense vs paged"
                );
            }
        }
    }
}

#[test]
fn golden_reference_checksum_is_stable_across_fanout_modes() {
    // The naive kernel fans whole heads; it must obey the same contract.
    let dist = Distribution::Uniform { x0: 2.0, am: 1.0 };
    let mh = gen_gqa_multihead(dist, 4, 2, 64, 64, 16, 7);
    let req = AttentionRequest::from_multihead(&mh, Allocation::Fa32).with_fp16_inputs();
    let _mode = pool::test_mode_guard();
    let pooled = pasa::attention::KernelRegistry::naive().forward(&req);
    pool::set_parallel(false);
    let sequential = pasa::attention::KernelRegistry::naive().forward(&req);
    pool::set_parallel(true);
    assert_eq!(checksum(&pooled), checksum(&sequential));
}
