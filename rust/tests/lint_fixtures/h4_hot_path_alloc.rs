//! Lint fixture: an allocating call inside a hot-path fence (linted under
//! a virtual `rust/src/tensor/` path). Must trip rule 4 (hot-path-alloc)
//! exactly once and no other rule.

// lint: hot-path — fixture fence.
pub fn scale_rows(out: &mut [f32], src: &[f32], s: f32) {
    let staged = src.to_vec();
    for (o, x) in out.iter_mut().zip(staged) {
        *o = x * s;
    }
}
// lint: end-hot-path
