//! Lint fixture: a properly `SAFETY:`-commented `unsafe impl` that does
//! **not** appear in the audit registry. `lint_file` alone reports nothing
//! (the comment is present); the registry cross-check must flag it as the
//! only violation.

pub struct Token(*mut u8);

// SAFETY: the pointer is never dereferenced; it is an opaque id.
unsafe impl Send for Token {}
