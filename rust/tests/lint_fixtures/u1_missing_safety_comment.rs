//! Lint fixture: an `unsafe` block with no `SAFETY:` comment anywhere in
//! the comment run above it. Must trip rule 1 (unsafe-audit) exactly once
//! and no other rule.
//!
//! This file is test data for `rust/tests/lint_invariants.rs` — it is
//! excluded from compilation (explicit `[[test]]` targets only) and from
//! the real tree walk (`lint_fixtures/` is skipped).

pub fn read_first(v: &[f32]) -> f32 {
    // A comment that is not a safety argument.
    unsafe { *v.as_ptr() }
}
