//! Lint fixture: a raw FP16 overflow-boundary literal in non-test code of
//! a non-exempt file. Must trip rule 2 (boundary-literal) exactly once and
//! no other rule.

pub fn clamp_to_fp16(x: f32) -> f32 {
    let boundary = 65504.0_f32;
    x.clamp(-boundary, boundary)
}
