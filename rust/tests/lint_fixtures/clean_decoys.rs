//! Lint fixture: every rule's *near-miss* in one file — each decoy looks
//! like a violation to a naive grep but is legal under the real rules.
//! Must produce zero violations when linted under a virtual
//! `rust/src/attention/` path (non-exempt for rule 2, scoped for rule 4).

/// Decoy 1: boundary values in comments (the FP16 max is 65504, E4M3
/// saturates at 448) and in strings are documentation, not code.
pub fn describe() -> &'static str {
    "overflow at 65504 (fp16) / 448 (e4m3) / 240 (e4m3-uz)"
}

/// Decoy 2: a `_` arm over an *unprotected* enum, and protected-enum
/// names appearing only in arm expressions.
pub fn pick(i: usize) -> AttnMask {
    match i {
        0 => AttnMask::None,
        1 => AttnMask::Causal,
        _ => AttnMask::Padded(Vec::new()),
    }
}

/// Decoy 3: allocation outside any fence is fine, and a fenced region
/// using only the allowed amortized-growth calls is fine too.
pub fn warm(buf: &mut Vec<f32>, n: usize) -> Vec<f32> {
    let staged = vec![0.0; n];
    // lint: hot-path — fixture fence with only allowed calls.
    buf.clear();
    buf.extend(staged.iter().copied());
    // lint: end-hot-path
    staged
}

/// Decoy 4: `unsafe` in a string and a lifetime that must not be eaten as
/// a char literal.
pub fn tell<'a>(s: &'a str) -> (&'a str, char) {
    let kw = "unsafe { not_code() }";
    let c = 'x';
    (if s.is_empty() { kw } else { s }, c)
}

/// Decoy 5: numeric near-misses — identifier tails, tuple fields, and
/// values close to (but not equal to) the boundaries.
pub fn near(pair: (f32, f32), x448: f32) -> f32 {
    pair.0 + x448 + 65503.0 + 44.8 + 2.40
}
