//! Lint fixture: a `_` catch-all in a `match` over the precision-critical
//! `Allocation` enum. Must trip rule 3 (wildcard-arm) exactly once and no
//! other rule.

pub fn is_eight_bit(alloc: Allocation) -> bool {
    match alloc {
        Allocation::Fp8 => true,
        Allocation::Pasa8 => true,
        _ => false,
    }
}
