//! Tier-1 gate for `pasa lint` (S14): the tree itself must be clean, and
//! each fixture in `rust/tests/lint_fixtures/` must trip **exactly** its
//! intended rule — the fixtures are the lint's own regression corpus, so
//! a scanner or rule change that goes blind (or trigger-happy) fails here
//! before it ever reaches CI.
//!
//! The fixtures are linted under *virtual* repo paths (e.g. a tensor-dir
//! path for the hot-path fixture) because rule scoping is path-based; the
//! files themselves are excluded from compilation and from the real tree
//! walk.

use pasa::analysis::{lint_file, lint_tree, unsafe_audit, Rule, UnsafeKind};
use std::path::Path;

#[test]
fn tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let violations = lint_tree(root).expect("tree walk");
    for v in &violations {
        eprintln!("{v}");
    }
    assert!(
        violations.is_empty(),
        "pasa lint found {} violation(s) — see stderr",
        violations.len()
    );
}

/// Lint a fixture under a virtual repo path and return its violations.
fn fixture(rel: &str, src: &str) -> Vec<pasa::analysis::Violation> {
    lint_file(rel, src).violations
}

fn assert_single(rel: &str, src: &str, rule: Rule) {
    let v = fixture(rel, src);
    assert_eq!(v.len(), 1, "expected exactly one violation, got {v:?}");
    assert_eq!(v[0].rule, rule, "{}", v[0]);
}

#[test]
fn fixture_u1_missing_safety_comment() {
    assert_single(
        "rust/src/coordinator/fixture_u1.rs",
        include_str!("lint_fixtures/u1_missing_safety_comment.rs"),
        Rule::UnsafeAudit,
    );
}

#[test]
fn fixture_u1_unaudited_unsafe() {
    // The site carries its SAFETY comment, so the per-file pass is clean —
    // only the registry cross-check may flag it.
    let src = include_str!("lint_fixtures/u1_unaudited_unsafe.rs");
    let rep = lint_file("rust/src/coordinator/fixture_u1b.rs", src);
    assert!(rep.violations.is_empty(), "{:?}", rep.violations);
    assert_eq!(rep.unsafe_sites.len(), 1);
    assert_eq!(rep.unsafe_sites[0].kind, UnsafeKind::Impl);
    let v = unsafe_audit::check_against(&rep.unsafe_sites, &[]);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].rule, Rule::UnsafeAudit);
    assert!(v[0].message.contains("audit registry"), "{}", v[0]);
}

#[test]
fn fixture_b2_boundary_literal() {
    assert_single(
        "rust/src/coordinator/fixture_b2.rs",
        include_str!("lint_fixtures/b2_boundary_literal.rs"),
        Rule::BoundaryLiteral,
    );
}

#[test]
fn fixture_m3_wildcard_arm() {
    assert_single(
        "rust/src/coordinator/fixture_m3.rs",
        include_str!("lint_fixtures/m3_wildcard_arm.rs"),
        Rule::WildcardArm,
    );
}

#[test]
fn fixture_h4_hot_path_alloc() {
    assert_single(
        "rust/src/tensor/fixture_h4.rs",
        include_str!("lint_fixtures/h4_hot_path_alloc.rs"),
        Rule::HotPathAlloc,
    );
}

#[test]
fn fixture_clean_decoys_produce_nothing() {
    let src = include_str!("lint_fixtures/clean_decoys.rs");
    let rep = lint_file("rust/src/attention/fixture_clean.rs", src);
    assert!(rep.violations.is_empty(), "{:?}", rep.violations);
    assert!(rep.unsafe_sites.is_empty(), "{:?}", rep.unsafe_sites);
}

#[test]
fn fixtures_are_rule_scoped_by_path() {
    // The same hot-path fixture under a non-scoped path is clean, and the
    // boundary fixture inside `numerics/` is exempt: path scoping is part
    // of the rules' contract, pinned here so a refactor cannot drop it.
    let h4 = include_str!("lint_fixtures/h4_hot_path_alloc.rs");
    assert!(fixture("rust/src/model/fixture_h4.rs", h4).is_empty());
    let b2 = include_str!("lint_fixtures/b2_boundary_literal.rs");
    assert!(fixture("rust/src/numerics/fixture_b2.rs", b2).is_empty());
}
