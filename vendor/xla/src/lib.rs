//! Offline stub of the `xla-rs` PJRT surface used by `pasa::runtime`.
//!
//! The serving runtime loads AOT HLO-text artifacts through PJRT. In
//! environments without the native XLA backend this stub keeps the crate
//! compiling: [`Literal`] is a real in-memory container (so literal
//! plumbing and shape checks still work), while every operation that would
//! need the native runtime — client creation, module parsing, compilation,
//! execution — returns [`XlaError`]. Callers already degrade gracefully:
//! the integration tests and examples skip when `artifacts/` is absent,
//! and `ModelRuntime::load` surfaces the error otherwise.

use std::borrow::Borrow;
use std::fmt;

/// Error type of the stubbed PJRT layer.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(XlaError(format!(
        "{what} unavailable: built with the offline xla stub (no native PJRT backend)"
    )))
}

/// Element payload of a [`Literal`].
#[derive(Clone, Debug)]
pub enum Elems {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Elems {
    fn len(&self) -> usize {
        match self {
            Elems::F32(v) => v.len(),
            Elems::I32(v) => v.len(),
        }
    }
}

/// Types a [`Literal`] can hold natively.
pub trait NativeType: Copy {
    fn wrap(data: &[Self]) -> Elems;
    fn unwrap(e: &Elems) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn wrap(data: &[Self]) -> Elems {
        Elems::F32(data.to_vec())
    }
    fn unwrap(e: &Elems) -> Option<Vec<Self>> {
        match e {
            Elems::F32(v) => Some(v.clone()),
            Elems::I32(_) => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: &[Self]) -> Elems {
        Elems::I32(data.to_vec())
    }
    fn unwrap(e: &Elems) -> Option<Vec<Self>> {
        match e {
            Elems::I32(v) => Some(v.clone()),
            Elems::F32(_) => None,
        }
    }
}

/// In-memory literal: a flat buffer plus dims, or a tuple of literals.
#[derive(Clone, Debug)]
pub enum Literal {
    Array { elems: Elems, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build a rank-1 literal from a native slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal::Array {
            elems: T::wrap(data),
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { elems, .. } => {
                let n: i64 = dims.iter().product();
                if n as usize != elems.len() {
                    return Err(XlaError(format!(
                        "reshape: {} elements into dims {dims:?}",
                        elems.len()
                    )));
                }
                Ok(Literal::Array {
                    elems: elems.clone(),
                    dims: dims.to_vec(),
                })
            }
            Literal::Tuple(_) => Err(XlaError("reshape on a tuple literal".into())),
        }
    }

    /// Flatten a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(v) => Ok(v),
            lit @ Literal::Array { .. } => Ok(vec![lit]),
        }
    }

    /// Copy the buffer out as a native vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { elems, .. } => T::unwrap(elems)
                .ok_or_else(|| XlaError("literal element type mismatch".into())),
            Literal::Tuple(_) => Err(XlaError("to_vec on a tuple literal".into())),
        }
    }
}

/// Parsed HLO module (stub: parsing requires the native backend).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HLO text parsing")
    }
}

/// XLA computation wrapper.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client (stub: construction always fails offline).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PJRT CPU client")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("XLA compilation")
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("device-to-host transfer")
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T: Borrow<Literal>>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execution")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_plumbing_works_offline() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 2]).is_err());
        let i = Literal::vec1(&[7i32]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let msg = format!("{}", PjRtClient::cpu().unwrap_err());
        assert!(msg.contains("offline xla stub"));
    }
}
