//! Minimal offline stand-in for the `anyhow` crate.
//!
//! Implements the subset of the real API this workspace uses: the opaque
//! [`Error`] type with context chaining, the [`Result`] alias, the
//! [`Context`] extension trait for `Result` and `Option`, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Like the real crate, [`Error`]
//! deliberately does **not** implement `std::error::Error`, which is what
//! makes the blanket `From<E: std::error::Error>` conversion coherent.

use std::fmt;

/// Opaque error: a rendered message chain (outermost context first).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn new(msg: String) -> Error {
        Error { msg }
    }

    /// Parity with `anyhow::Error::msg`.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error {
            msg: format!("{c}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Render the source chain eagerly; the lab only ever displays it.
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from format arguments.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::new(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from format arguments.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("boom {}", 42)
    }

    #[test]
    fn macros_and_context() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer: boom 42");
        let o: Option<u8> = None;
        assert!(o.context("missing").is_err());
        let io: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "disk on fire",
        ));
        let wrapped: Result<()> = io.map_err(Error::from);
        assert!(format!("{:?}", wrapped.unwrap_err()).contains("disk on fire"));
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            Ok(x)
        }
        assert_eq!(check(3).unwrap(), 3);
        assert!(check(30).is_err());
    }
}
