"""L1 flash kernel vs pure-jnp oracle — the core correctness signal."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.kernels.flash import flash_attention, ALLOCATIONS
from compile.kernels.ref import (
    attention_ref,
    attention_ref_masked,
    attention_fp16_partial_ref,
    relative_rmse,
)


def _case(seed, s, d, x0=0.0, am=1.0):
    rng = np.random.default_rng(seed)
    mk = lambda: (rng.uniform(-am, am, (s, d)) + x0).astype(np.float32)
    return jnp.asarray(mk()), jnp.asarray(mk()), jnp.asarray(mk())


@pytest.mark.parametrize("alloc", ALLOCATIONS)
def test_matches_ref_on_benign_data(alloc):
    q, k, v = _case(0, 200, 64)
    o = flash_attention(q, k, v, allocation=alloc)
    g = attention_ref(q, k, v)
    tol = {"fa32": 2e-3, "fa16_32": 5e-3, "fa16": 3e-2}[alloc]
    assert relative_rmse(o, g) < tol


def test_block_size_invariance():
    q, k, v = _case(1, 160, 32, x0=2.0)
    g = attention_ref(q, k, v)
    for bq, bkv in [(32, 32), (64, 64), (128, 128), (64, 32)]:
        o = flash_attention(q, k, v, allocation="fa32", block_q=bq, block_kv=bkv)
        assert relative_rmse(o, g) < 2e-3, (bq, bkv)


def test_fa16_32_overflows_on_large_mean():
    # Fig. 9(a) x0=30: S ~ 30*30*128 = 115200 > 65504.
    q, k, v = _case(2, 256, 128, x0=30.0, am=0.5)
    o = flash_attention(q, k, v, allocation="fa16_32")
    assert not bool(jnp.isfinite(o).all()), "expected NaN from FP16 store overflow"
    o32 = flash_attention(q, k, v, allocation="fa32")
    assert bool(jnp.isfinite(o32).all())


def test_fa16_32_matches_partial_ref_failure_mode():
    q, k, v = _case(3, 256, 128, x0=30.0, am=0.5)
    ref = attention_fp16_partial_ref(q, k, v)
    ker = flash_attention(q, k, v, allocation="fa16_32")
    # Both paths must agree that the computation blew up.
    assert bool(jnp.isfinite(ref).all()) == bool(jnp.isfinite(ker).all()) == False  # noqa: E712


def test_kv_len_masking():
    q, k, v = _case(4, 96, 32)
    o = flash_attention(q, k, v, kv_len=50, allocation="fa32", block_q=32, block_kv=32)
    g = attention_ref_masked(q, k, v, kv_len=50)
    assert relative_rmse(o, g) < 2e-3
    # Padding K/V rows beyond kv_len must not change the output.
    k2 = k.at[50:].set(1e4)
    v2 = v.at[50:].set(-1e4)
    o2 = flash_attention(q, k2, v2, kv_len=50, allocation="fa32", block_q=32, block_kv=32)
    assert relative_rmse(o2, o) < 1e-6


def test_causal_masking():
    q, k, v = _case(5, 64, 16)
    o = flash_attention(q, k, v, causal=True, allocation="fa32", block_q=32, block_kv=32)
    g = attention_ref_masked(q, k, v, causal=True)
    assert relative_rmse(o, g) < 2e-3


def test_decode_shape_q1():
    # Single-query decode against a longer KV (the serving hot path).
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.normal(0, 1, (1, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (128, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (128, 32)).astype(np.float32))
    o = flash_attention(q, k, v, kv_len=77, allocation="fa32", block_q=32, block_kv=64)
    g = attention_ref_masked(q, k, v, kv_len=77)
    assert o.shape == (1, 32)
    assert relative_rmse(o, g) < 2e-3
