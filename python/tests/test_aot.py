"""AOT export pipeline: HLO text must be runnable plain-HLO (no
custom-calls) and the weights format must round-trip."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import make_head_fn, make_prefill_fn, make_decode_fn, spec, to_hlo_text
from compile.train import save_weights, load_weights


def test_head_module_lowering():
    fn = make_head_fn("pasa")
    text = to_hlo_text(jax.jit(fn).lower(*[spec((128, 32))] * 3))
    assert "custom-call" not in text, "Mosaic custom-call would not run on CPU PJRT"
    assert "ENTRY" in text


def test_prefill_decode_lowering_small():
    cfg = M.ModelConfig(
        n_layers=1, d_model=32, n_heads=1, d_head=32, d_ff=64, max_seq=32,
        block_q=32, block_kv=32,
    )
    names = M.param_names(cfg)
    shapes = M.param_shapes(cfg)
    pspecs = [spec(shapes[n]) for n in names]

    pf = make_prefill_fn(cfg)
    text = to_hlo_text(
        jax.jit(pf).lower(*pspecs, spec((1, 16), jnp.int32), spec((1,), jnp.int32))
    )
    assert "custom-call" not in text

    df = make_decode_fn(cfg)
    cache = spec((cfg.n_layers, 2, cfg.max_seq, cfg.head_width))
    text = to_hlo_text(
        jax.jit(df).lower(
            *pspecs, spec((2,), jnp.int32), spec((2,), jnp.int32), cache, cache
        )
    )
    assert "custom-call" not in text


def test_weights_round_trip(tmp_path):
    cfg = M.ModelConfig(n_layers=1, d_model=32, n_heads=1, d_head=32, d_ff=64, max_seq=32)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    path = os.path.join(tmp_path, "w.bin")
    save_weights(path, params, cfg)
    loaded = load_weights(path)
    assert set(loaded) == set(params)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(loaded[k]))


def test_manifest_artifacts_exist_if_built():
    """If `make artifacts` has run, the manifest's modules must exist."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built")
    for line in open(manifest):
        parts = line.split()
        if parts and parts[0] == "module":
            assert os.path.exists(os.path.join(art, parts[2])), parts[2]
