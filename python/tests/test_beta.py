"""Optimal accuracy condition (Appendix A/C, Table 3) — python solver."""

import numpy as np
import pytest

from compile.optimal_para import obtain_inv_pam, optimal_beta


def test_paper_solutions_at_n128():
    # Appendix A: initials 1-2^-4, 1-2^-5, 1-2^-6 solve to
    # 0.937500, 0.968994, 0.984497.
    expect = [0.937500, 0.968994, 0.984497]
    for i, p in enumerate([4, 5, 6]):
        b = optimal_beta(1.0 - 2.0**-p, 128)
        assert abs(b - expect[i]) < 5e-6, (p, b)


def test_fixed_point_is_consistent():
    # At the solution, beta/(1-beta) equals the practical invariant.
    for b0 in [0.9, 0.99, 0.999]:
        b = optimal_beta(b0, 128)
        inva = b / (1 - b)
        inva1 = obtain_inv_pam(b, 128)
        assert abs(inva - inva1) / inva < 1e-9


def test_table3_initial_rel_errors():
    # Paper Table 3: initial-beta relative invariance errors.
    rows = {
        0.9: 0.0032,
        1 - 2.0**-4: 0.0,
        1 - 2.0**-5: 0.0081,
        1 - 2.0**-6: 0.0079,
        0.99: 0.0323,
        0.999: 0.0320,
    }
    for b0, expected in rows.items():
        inva = b0 / (1 - b0)
        inva1 = obtain_inv_pam(b0, 128)
        rel = abs(inva - inva1) / inva
        assert abs(rel - expected) < 6e-4, (b0, rel, expected)


def test_beta_0p9375_exact_in_fp16():
    # 0.9375 has integer invariant 15 and is exact in FP16: zero error.
    assert obtain_inv_pam(0.9375, 128) == pytest.approx(15.0, abs=1e-12)


def test_matches_rust_effective_invariant_shape():
    # The kernel-side effective invariant (alpha-folded M) must be close
    # to (but not necessarily equal to) the ideal invariant.
    from compile.kernels.pasa import shifting_matrix, effective_invariant

    for n in [32, 64, 128]:
        m = shifting_matrix(n, alpha=np.sqrt(128.0), beta=0.984497)
        c = effective_invariant(m)
        ideal = 0.984497 / (1 - 0.984497)
        assert abs(c - ideal) / ideal < 0.1, (n, c)
