"""L2 model tests: shapes, prefill/decode parity, mask correctness."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def small():
    cfg = M.ModelConfig(
        n_layers=2, d_model=64, n_heads=2, d_head=32, d_ff=128, max_seq=64,
        block_q=32, block_kv=32,
    )
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_param_inventory(small):
    cfg, params = small
    names = M.param_names(cfg)
    shapes = M.param_shapes(cfg)
    assert set(names) == set(params.keys()) == set(shapes.keys())
    for n in names:
        assert tuple(params[n].shape) == tuple(shapes[n]), n


def test_prefill_shapes(small):
    cfg, params = small
    toks = jnp.zeros((2, 16), jnp.int32)
    logits, kc, vc = M.prefill(params, toks, jnp.asarray([16, 8]), cfg)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert kc.shape == (cfg.n_layers, 2, cfg.max_seq, cfg.head_width)
    assert vc.shape == kc.shape
    # Cache beyond the prompt is zero-padded.
    assert bool((kc[:, :, 16:, :] == 0).all())


def test_decode_matches_prefill(small):
    cfg, params = small
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 255, (1, 12)), jnp.int32)
    _, kc, vc = M.prefill(params, toks, jnp.asarray([12]), cfg)
    # Decode token 12 and compare with a longer prefill.
    nxt = jnp.asarray([42], jnp.int32)
    dec_logits, _, _ = M.decode_step(
        params, nxt, jnp.asarray([12], jnp.int32),
        jnp.repeat(kc, 1, axis=1), jnp.repeat(vc, 1, axis=1), cfg,
    )
    toks2 = jnp.concatenate([toks, nxt[None, :]], axis=1)
    pf_logits, _, _ = M.prefill(params, toks2, jnp.asarray([13]), cfg)
    np.testing.assert_allclose(
        np.asarray(dec_logits[0]), np.asarray(pf_logits[0, 12]), atol=2e-3, rtol=1e-2
    )


def test_padding_tokens_do_not_affect_prefix(small):
    cfg, params = small
    rng = np.random.default_rng(1)
    base = rng.integers(0, 255, (1, 16))
    a = base.copy()
    b = base.copy()
    b[0, 10:] = 99  # garbage beyond seq_len
    la, _, _ = M.prefill(params, jnp.asarray(a, jnp.int32), jnp.asarray([10]), cfg)
    lb, _, _ = M.prefill(params, jnp.asarray(b, jnp.int32), jnp.asarray([10]), cfg)
    np.testing.assert_allclose(
        np.asarray(la[0, :10]), np.asarray(lb[0, :10]), atol=2e-3, rtol=1e-2
    )


def test_attention_allocations_agree_on_benign_weights(small):
    cfg, params = small
    toks = jnp.asarray(np.random.default_rng(2).integers(0, 255, (1, 16)), jnp.int32)
    outs = {}
    for alloc in ["pasa", "fa32", "ref"]:
        c = M.ModelConfig(**{**cfg.__dict__, "attention": alloc})
        outs[alloc], _, _ = M.prefill(params, toks, jnp.asarray([16]), c)
    a = np.asarray(outs["pasa"][0, :16])
    b = np.asarray(outs["fa32"][0, :16])
    r = np.asarray(outs["ref"][0, :16])
    assert np.abs(a - r).max() < 0.1  # fp16 kernel vs fp32 ref: small drift
    assert np.abs(b - r).max() < 0.05


def test_encode_decode_text_round_trip():
    ids, n = M.encode_text("hello", 16)
    assert n == 6  # BOS + 5 bytes
    assert ids[0] == M.BOS and ids[n:].tolist() == [M.PAD] * (16 - n)
    assert M.decode_bytes(ids.tolist()) == "hello"
