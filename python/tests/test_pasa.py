"""PASA Pallas kernel vs oracle, including hypothesis shape/dtype sweeps."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pasa import (
    pasa_attention,
    shifting_matrix,
    effective_invariant,
    DEFAULT_BETA,
)
from compile.kernels.flash import flash_attention
from compile.kernels.ref import (
    attention_ref,
    attention_ref_masked,
    attention_fp16_partial_ref,
    relative_rmse,
)


def _case(seed, s, d, x0=0.0, am=1.0):
    rng = np.random.default_rng(seed)
    mk = lambda: (rng.uniform(-am, am, (s, d)) + x0).astype(np.float32)
    return jnp.asarray(mk()), jnp.asarray(mk()), jnp.asarray(mk())


def test_matches_ref_on_benign_data():
    q, k, v = _case(0, 200, 64)
    o = pasa_attention(q, k, v)
    assert relative_rmse(o, attention_ref(q, k, v)) < 2e-2


def test_survives_overflow_case_where_fa16_32_dies():
    # The paper's headline: x0=30 uniform overflows partial-LP FA; PASA
    # stays finite and accurate (Fig. 9a).
    q, k, v = _case(1, 256, 128, x0=30.0, am=0.5)
    fa = attention_fp16_partial_ref(q, k, v)
    assert not bool(jnp.isfinite(fa).all()), "premise: FA16-32 overflows"
    o = pasa_attention(q, k, v)
    assert bool(jnp.isfinite(o).all())
    assert relative_rmse(o, attention_ref(q, k, v)) < 2e-2


def test_strongly_negative_mean():
    # SVD-like regime: all scores deeply negative.
    q, k, v = _case(2, 192, 128, x0=-25.0, am=0.5)
    o = pasa_attention(q, k, v)
    assert bool(jnp.isfinite(o).all())
    assert relative_rmse(o, attention_ref(q, k, v)) < 2e-2


def test_beta_zero_degrades_to_fa():
    # §2.2: beta = 0 -> PASA is plain FA2.
    q, k, v = _case(3, 128, 32, x0=1.0)
    p = pasa_attention(q, k, v, beta=0.0, block_q=64, block_kv=64)
    f = flash_attention(q, k, v, allocation="fa16", block_q=64, block_kv=64)
    assert relative_rmse(p, f) < 5e-3


def test_block_size_invariance():
    q, k, v = _case(4, 160, 32, x0=5.0, am=2.0)
    g = attention_ref(q, k, v)
    for bq, bkv in [(32, 32), (64, 64), (128, 128), (64, 32)]:
        o = pasa_attention(q, k, v, block_q=bq, block_kv=bkv)
        assert relative_rmse(o, g) < 2e-2, (bq, bkv)


def test_causal_and_kv_len():
    q, k, v = _case(5, 96, 32)
    o = pasa_attention(q[:48], k, v, kv_len=70, q_pos0=22, causal=True,
                       block_q=32, block_kv=32)
    g = attention_ref_masked(q[:48], k, v, kv_len=70, q_pos0=22, causal=True)
    assert relative_rmse(o, g) < 2e-2


def test_padding_rows_do_not_leak():
    q, k, v = _case(6, 80, 16)
    o = pasa_attention(q, k, v, kv_len=60, block_q=32, block_kv=32)
    # Zeroed padding (the serving KV-cache convention) and moderate
    # garbage are masked out and recovered exactly.
    k2 = k.at[60:].set(0.0)
    v2 = v.at[60:].set(0.0)
    o2 = pasa_attention(q, k2, v2, kv_len=60, block_q=32, block_kv=32)
    assert relative_rmse(o2, o) < 2e-2
    k3 = k.at[60:].set(5.0)
    v3 = v.at[60:].set(-5.0)
    o3 = pasa_attention(q, k3, v3, kv_len=60, block_q=32, block_kv=32)
    assert relative_rmse(o3, o) < 2e-2


def test_extreme_padding_garbage_degrades_accuracy_known_limitation():
    """Documented PASA property: masked rows *do* enter the block
    pseudo-average (the recovery is algebraically exact but FP16 loses
    resolution when garbage inflates the shift). Serving therefore zeroes
    cache padding — this test pins the failure mode down so a regression
    in masking order would be caught."""
    q, k, v = _case(6, 80, 16)
    o = pasa_attention(q, k, v, kv_len=60, block_q=32, block_kv=32)
    k2 = k.at[60:].set(500.0)
    o2 = pasa_attention(q, k2, v, kv_len=60, block_q=32, block_kv=32)
    # Still finite (no overflow), but visibly degraded.
    assert bool(jnp.isfinite(o2).all())
    assert relative_rmse(o2, o) > 1e-3


def test_shifting_matrix_structure():
    m = shifting_matrix(128, alpha=np.sqrt(128.0), beta=DEFAULT_BETA)
    assert m.dtype == np.float16
    assert np.all(m[0, 1:] == m[0, 1])  # constant off-diagonal
    c = effective_invariant(m)
    # Ballpark of the ideal beta/(1-beta) = 63.5.
    assert 40.0 < c < 90.0


@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(8, 200),
    d=st.sampled_from([8, 16, 32, 64]),
    x0=st.sampled_from([0.0, 3.0, -8.0, 15.0]),
    seed=st.integers(0, 10_000),
)
def test_hypothesis_shapes_and_means(s, d, x0, seed):
    """Property: PASA output is finite and tracks the oracle across random
    shapes, head dims and data biases (the paper's robustness claim)."""
    q, k, v = _case(seed, s, d, x0=x0, am=1.0)
    o = pasa_attention(q, k, v, block_q=64, block_kv=64)
    assert o.shape == (s, d)
    assert bool(jnp.isfinite(o).all())
    g = attention_ref(q, k, v)
    assert relative_rmse(o, g) < 5e-2


@settings(max_examples=6, deadline=None)
@given(dtype=st.sampled_from([np.float32, np.float16]), seed=st.integers(0, 100))
def test_hypothesis_input_dtypes(dtype, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (64, 32)).astype(dtype))
    k = jnp.asarray(rng.normal(0, 1, (64, 32)).astype(dtype))
    v = jnp.asarray(rng.normal(0, 1, (64, 32)).astype(dtype))
    o = pasa_attention(q, k, v, block_q=32, block_kv=32)
    g = attention_ref(q, k, v)
    assert o.dtype == jnp.float32
    assert relative_rmse(o, g) < 5e-2
