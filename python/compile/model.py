"""L2 — the JAX transformer served by the rust coordinator.

A GPT-style byte-level LM whose attention is the L1 Pallas kernel (PASA by
default, or any FA allocation for the baselines). Exposes the two entry
points the serving runtime AOT-compiles:

* `prefill(params, tokens, seq_len)`  — process a prompt, build KV caches,
* `decode_step(params, token, pos, kcache, vcache)` — one token step
  against the caches (the serving hot loop).

Weights are a flat dict with a deterministic parameter order
(`param_names`) shared with the rust weight loader; see aot.py for the
on-disk format.
"""

import dataclasses
import functools
import math
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.flash import flash_attention
from .kernels.pasa import pasa_attention

Params = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + serving-shape configuration."""

    vocab_size: int = 259  # 256 bytes + PAD(256) + BOS(257) + EOS(258)
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    d_head: int = 32
    d_ff: int = 1024
    max_seq: int = 512
    attention: str = "pasa"  # 'pasa' | 'fa32' | 'fa16_32' | 'fa16'
    block_q: int = 128
    block_kv: int = 128

    @property
    def head_width(self) -> int:
        return self.n_heads * self.d_head


PAD, BOS, EOS = 256, 257, 258


def param_names(cfg: ModelConfig) -> List[str]:
    """Deterministic parameter order — the rust loader's contract."""
    names = ["tok_emb", "pos_emb"]
    for i in range(cfg.n_layers):
        names += [
            f"l{i}.ln1_g",
            f"l{i}.ln1_b",
            f"l{i}.wq",
            f"l{i}.wk",
            f"l{i}.wv",
            f"l{i}.wo",
            f"l{i}.ln2_g",
            f"l{i}.ln2_b",
            f"l{i}.w1",
            f"l{i}.b1",
            f"l{i}.w2",
            f"l{i}.b2",
        ]
    names += ["lnf_g", "lnf_b"]
    return names


def param_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d, h = cfg.d_model, cfg.head_width
    shapes = {
        "tok_emb": (cfg.vocab_size, d),
        "pos_emb": (cfg.max_seq, d),
        "lnf_g": (d,),
        "lnf_b": (d,),
    }
    for i in range(cfg.n_layers):
        shapes.update(
            {
                f"l{i}.ln1_g": (d,),
                f"l{i}.ln1_b": (d,),
                f"l{i}.wq": (d, h),
                f"l{i}.wk": (d, h),
                f"l{i}.wv": (d, h),
                f"l{i}.wo": (h, d),
                f"l{i}.ln2_g": (d,),
                f"l{i}.ln2_b": (d,),
                f"l{i}.w1": (d, cfg.d_ff),
                f"l{i}.b1": (cfg.d_ff,),
                f"l{i}.w2": (cfg.d_ff, d),
                f"l{i}.b2": (d,),
            }
        )
    return shapes


def init_params(key, cfg: ModelConfig) -> Params:
    """Scaled-normal init (0.02, residual projections down-scaled)."""
    params = {}
    shapes = param_shapes(cfg)
    for name in param_names(cfg):
        shape = shapes[name]
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b", ".b1", ".b2")):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            scale = 0.02
            if name.endswith((".wo", ".w2")):
                scale = 0.02 / math.sqrt(2 * cfg.n_layers)
            params[name] = scale * jax.random.normal(sub, shape, jnp.float32)
    return params


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _attention_fn(cfg: ModelConfig):
    """Per-head kernel closure for the configured allocation."""
    if cfg.attention == "pasa":
        return functools.partial(
            pasa_attention, block_q=cfg.block_q, block_kv=cfg.block_kv
        )
    if cfg.attention == "ref":
        # Pure-jnp float32 attention — differentiable, used by train.py
        # (the Pallas kernels are inference kernels; training runs the
        # mathematically-identical reference).
        from .kernels.ref import attention_ref_masked

        def ref_kern(q, k, v, kv_len=None, q_pos0=0, causal=False):
            return attention_ref_masked(
                q, k, v, kv_len=kv_len, q_pos0=q_pos0, causal=causal
            )

        return ref_kern
    return functools.partial(
        flash_attention,
        allocation=cfg.attention,
        block_q=cfg.block_q,
        block_kv=cfg.block_kv,
    )


def _mha(cfg: ModelConfig, q, k, v, kv_len, q_pos0, causal):
    """Multi-head attention via the L1 kernel, vmapped over (B, H).

    q: (B, S1, H*dh); k, v: (B, S2, H*dh) -> (B, S1, H*dh).
    kv_len, q_pos0: (B,) int32 per-sequence lengths/positions.
    """
    b, s1, _ = q.shape
    s2 = k.shape[1]
    h, dh = cfg.n_heads, cfg.d_head
    qh = q.reshape(b, s1, h, dh).transpose(0, 2, 1, 3)  # (B,H,S1,dh)
    kh = k.reshape(b, s2, h, dh).transpose(0, 2, 1, 3)
    vh = v.reshape(b, s2, h, dh).transpose(0, 2, 1, 3)
    kern = _attention_fn(cfg)

    def per_head(qi, ki, vi, kvl, qp0):
        return kern(qi, ki, vi, kv_len=kvl, q_pos0=qp0, causal=causal)

    per_seq = jax.vmap(per_head, in_axes=(0, 0, 0, None, None))  # over H
    out = jax.vmap(per_seq, in_axes=(0, 0, 0, 0, 0))(qh, kh, vh, kv_len, q_pos0)
    return out.transpose(0, 2, 1, 3).reshape(b, s1, h * dh)


def _block(cfg: ModelConfig, params, i, x, k_all, v_all, kv_len, q_pos0, causal):
    """One transformer block; k_all/v_all are the (possibly cached) KV."""
    p = lambda n: params[f"l{i}.{n}"]
    h = _layer_norm(x, p("ln1_g"), p("ln1_b"))
    q = h @ p("wq")
    attn = _mha(cfg, q, k_all, v_all, kv_len, q_pos0, causal)
    x = x + attn @ p("wo")
    h = _layer_norm(x, p("ln2_g"), p("ln2_b"))
    x = x + (jax.nn.gelu(h @ p("w1") + p("b1")) @ p("w2") + p("b2"))
    return x


def prefill(params: Params, tokens, seq_len, cfg: ModelConfig):
    """Process a (B, S) prompt.

    Returns (logits (B, S, V), kcache, vcache) with caches shaped
    (n_layers, B, max_seq, H*dh) — KV for positions >= seq_len is zero.
    """
    b, s = tokens.shape
    x = params["tok_emb"][tokens] + params["pos_emb"][:s][None, :, :]
    pad = cfg.max_seq - s
    kcache = []
    vcache = []
    kv_len = seq_len.astype(jnp.int32)
    q_pos0 = jnp.zeros((b,), jnp.int32)
    for i in range(cfg.n_layers):
        h = _layer_norm(x, params[f"l{i}.ln1_g"], params[f"l{i}.ln1_b"])
        k = h @ params[f"l{i}.wk"]
        v = h @ params[f"l{i}.wv"]
        x = _block(cfg, params, i, x, k, v, kv_len, q_pos0, causal=True)
        kcache.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0))))
        vcache.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0))))
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = x @ params["tok_emb"].T
    return logits, jnp.stack(kcache), jnp.stack(vcache)


def decode_step(params: Params, token, pos, kcache, vcache, cfg: ModelConfig):
    """One decode step.

    token: (B,) int32 current tokens; pos: (B,) their absolute positions.
    kcache/vcache: (n_layers, B, max_seq, H*dh) — read-only inputs; the
    step's KV rows are scattered in internally for attention.

    Returns (logits (B, V), k_rows (n_layers, B, H*dh),
    v_rows (n_layers, B, H*dh)) — only the *new* rows are returned (§Perf:
    the rust coordinator owns the paged cache and writes the rows back
    itself; returning full caches moved 32 MB/step over the PJRT boundary
    for 32 KB of new information).
    """
    b = token.shape[0]
    x = params["tok_emb"][token] + params["pos_emb"][pos]
    x = x[:, None, :]  # (B, 1, D)
    kv_len = (pos + 1).astype(jnp.int32)
    new_k = []
    new_v = []
    for i in range(cfg.n_layers):
        h = _layer_norm(x, params[f"l{i}.ln1_g"], params[f"l{i}.ln1_b"])
        k_new = h @ params[f"l{i}.wk"]  # (B, 1, H*dh)
        v_new = h @ params[f"l{i}.wv"]
        # Scatter this step's KV into the cache at each sequence's pos.
        kc = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0)))(
            kcache[i], k_new, pos
        )
        vc = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0)))(
            vcache[i], v_new, pos
        )
        x = _block(cfg, params, i, x, kc, vc, kv_len, pos, causal=False)
        new_k.append(k_new[:, 0, :])
        new_v.append(v_new[:, 0, :])
    x = _layer_norm(x, params["lnf_g"], params["lnf_b"])
    logits = (x @ params["tok_emb"].T)[:, 0, :]
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def encode_text(text: str, max_len: int):
    """Byte-level encoding with BOS, padded to max_len with PAD."""
    ids = [BOS] + list(text.encode("utf-8"))[: max_len - 1]
    n = len(ids)
    return np.asarray(ids + [PAD] * (max_len - n), np.int32), n


def decode_bytes(ids) -> str:
    out = bytearray()
    for t in ids:
        if t in (PAD, BOS, EOS):
            continue
        if 0 <= t < 256:
            out.append(int(t))
    return out.decode("utf-8", errors="replace")
