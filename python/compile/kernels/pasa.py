"""PASA Pallas kernel (L1) — Algorithm 1 of the paper.

Fully-FP16 flash attention with online pseudo-average shifting and global
recovering:

* the shifting matrix M = (I - beta*J/s2)/alpha is built host-side in
  float16 (Eq. 10) and applied to every KV block as a batched GEMM
  (Eq. 11) — K' = M @ K,
* the kernel sweeps KV blocks with an online (m, l, F-bar, O) carry; the
  correction terms dm'_{j-1} = c*(F^{j-1} - F^j), dm'_j = c*(S'-bar - F^j)
  re-express each block's local softmax stats in a common frame
  (Theorem 2.1, Algorithm 1 lines 13-18),
* the correction factor c is the *effective invariant* of the rounded M
  (b'n/(a'-b'n)), matching the rust implementation — see DESIGN.md
  "PASA deviations" for why this zeroes the aliasing error that the
  nominal beta/(1-beta) leaves once alpha is folded into M.

interpret=True everywhere: real-TPU lowering would emit a Mosaic
custom-call that the CPU PJRT plugin cannot execute. On TPU the same
BlockSpec structure maps Q/K'/V tiles into VMEM and the two jnp.dot calls
onto the MXU (see DESIGN.md Hardware-Adaptation).
"""

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BETA = 0.984497  # the paper's adopted value (solved at n=128, FP16)
MASK_FLOOR = np.float16(-30000.0)  # finite FP16 "-inf" (avoids inf-inf=NaN)


def _exp16(x):
    """FP16 exp computed at FP32 internal precision, rounded once to FP16.

    Matches the rust lab's emulation (and real vector units' internal
    precision). Also required for portability: xla_extension 0.5.1's CPU
    f16 `exponential` mishandles large-negative inputs (masked scores at
    -30000 must flush to 0, not NaN), while computing in f32 and
    downcasting is correct on every backend.
    """
    return jnp.exp(x.astype(jnp.float32)).astype(jnp.float16)


def shifting_matrix(s2: int, alpha: float, beta: float) -> np.ndarray:
    """M = (I - beta*J/s2)/alpha rounded to FP16 (Eq. 10)."""
    off = np.float16(-beta / (s2 * alpha))
    diag = np.float16((1.0 - beta / s2) / alpha)
    m = np.full((s2, s2), off, dtype=np.float16)
    np.fill_diagonal(m, diag)
    return m


def effective_invariant(m: np.ndarray) -> float:
    """Recovery constant c of the *rounded* M: c = b'n/(a' - b'n).

    Adding c*rowmean(S') to S' = S @ M reproduces a'*S up to a per-row
    constant that softmax ignores (generalizes the paper's Eq. 20 to the
    alpha-folded M of Eq. 10).
    """
    n = m.shape[0]
    if n == 1:
        return 0.0
    off = -float(m[0, 1])
    if off == 0.0:
        return 0.0  # beta = 0: PASA degrades to FA2
    a = float(m[0, 0]) + off
    bn = off * n
    return bn / (a - bn)


def _pad_to(x, size, axis):
    pad = size - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pasa_kernel(
    lens_ref,
    q_ref,
    kp_ref,
    v_ref,
    o_ref,
    *,
    block_q: int,
    block_kv: int,
    n_kv: int,
    c_eff: float,
    causal: bool,
):
    """One Q block: sweep all KV blocks with the Algorithm-1 carry."""
    kv_len = lens_ref[0]
    q_pos0 = lens_ref[1]
    qb = q_ref[...].astype(jnp.float16)  # (block_q, d)
    d = qb.shape[-1]
    rows = q_pos0 + pl.program_id(0) * block_q + jax.lax.iota(jnp.int32, block_q)
    # The correction factor stays in f32 (precomputed host-side constant,
    # like the paper's FP64-solved beta): rounding c itself to FP16 would
    # put an Inva-amplified error back into the exponent.
    c32 = jnp.float32(c_eff)

    def body(j, carry):
        m, l, fbar, acc = carry
        kb = kp_ref[pl.dslice(j * block_kv, block_kv), :].astype(jnp.float16)
        vb = v_ref[pl.dslice(j * block_kv, block_kv), :].astype(jnp.float16)

        # Line 11: S' = Q K'^T — FP16 in, FP32 accumulate, FP16 store
        # (matrix-engine semantics).
        s = jnp.dot(qb, kb.T, preferred_element_type=jnp.float32).astype(jnp.float16)

        # Line 13: pseudo-average BEFORE masking (the recovery identity
        # S = S' + c*rowmean(S') is algebraic over the whole block).
        sbar = jnp.mean(s.astype(jnp.float32), axis=1).astype(jnp.float16)

        # Padding / causal mask.
        cols = j * block_kv + jax.lax.iota(jnp.int32, block_kv)
        valid = (cols < kv_len)[None, :]
        if causal:
            valid = valid & (cols[None, :] <= rows[:, None])
        s = jnp.where(valid, s, MASK_FLOOR)

        # Line 12: local stats.
        m_loc = jnp.max(s, axis=1)
        p = _exp16(s - m_loc[:, None])
        p = jnp.where(valid, p, jnp.float16(0.0))
        l_loc = jnp.sum(p.astype(jnp.float32), axis=1).astype(jnp.float16)

        # Line 14 (Eq. 15): running pseudo-average, incremental form.
        fbar_prev = fbar
        fbar = (fbar + (sbar - fbar) / jnp.float16(j + 1)).astype(jnp.float16)

        # Line 15: correction terms (f16 differences are Sterbenz-exact;
        # the c multiply runs in f32 and rounds once to f16).
        dm_prev = (c32 * (fbar_prev - fbar).astype(jnp.float32)).astype(jnp.float16)
        dm_cur = (c32 * (sbar - fbar).astype(jnp.float32)).astype(jnp.float16)

        # Line 16: corrected running maximum.
        m_new = jnp.maximum(m + dm_prev, m_loc + dm_cur)

        # Line 17: rescale exponents (both <= 0 — attenuators).
        scale_prev = _exp16((m - m_new) + dm_prev)
        scale_cur = _exp16((m_loc - m_new) + dm_cur)

        # Line 18: corrected softmax denominator.
        l = (scale_prev * l + scale_cur * l_loc).astype(jnp.float16)

        # Lines 19-20: corrected output update.
        pv = jnp.dot(p, vb, preferred_element_type=jnp.float32).astype(jnp.float16)
        acc = (scale_prev[:, None] * acc + scale_cur[:, None] * pv).astype(jnp.float16)
        return m_new, l, fbar, acc

    m0 = jnp.full((block_q,), MASK_FLOOR, jnp.float16)
    l0 = jnp.zeros((block_q,), jnp.float16)
    f0 = jnp.zeros((block_q,), jnp.float16)
    a0 = jnp.zeros((block_q, d), jnp.float16)
    _, l, _, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, f0, a0))

    # Line 22: O = O / l (guard padded rows against 0/0).
    l = jnp.maximum(l, jnp.float16(6e-8))
    o_ref[...] = (acc / l[:, None]).astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("beta", "block_q", "block_kv", "causal", "interpret"),
)
def pasa_attention(
    q,
    k,
    v,
    kv_len=None,
    q_pos0=0,
    *,
    beta: float = DEFAULT_BETA,
    block_q: int = 128,
    block_kv: int = 128,
    causal: bool = False,
    interpret: bool = True,
):
    """PASA attention over one head: q (S1, d), k/v (S2, d) -> (S1, d) f32.

    kv_len (scalar, default S2) marks valid KV rows; q_pos0 is the absolute
    position of q's first row (for causal decode against a longer cache).
    """
    s1, d = q.shape
    s2 = k.shape[0]
    alpha = math.sqrt(d)
    if kv_len is None:
        kv_len = s2

    s1p = max(block_q, ((s1 + block_q - 1) // block_q) * block_q)
    s2p = max(block_kv, ((s2 + block_kv - 1) // block_kv) * block_kv)
    n_kv = s2p // block_kv

    qp = _pad_to(q.astype(jnp.float16), s1p, 0)
    kp_in = _pad_to(k.astype(jnp.float16), s2p, 0)
    vp = _pad_to(v.astype(jnp.float16), s2p, 0)

    # Pre-processing (Algorithm 1 line 6): K'_j = M K_j per block, as FP16
    # GEMMs with FP32 accumulation. Statically unrolled plain 2-D dots, NOT
    # a batched einsum: xla_extension 0.5.1's CPU backend miscompiles
    # dot_general with batch dims on f16 operands (verified by the
    # differential op probes — see DESIGN.md §Runtime-portability).
    m_np = shifting_matrix(block_kv, alpha, beta)
    c_eff = effective_invariant(m_np)
    m16 = jnp.asarray(m_np)
    kb = kp_in.reshape(n_kv, block_kv, d)
    kprime = jnp.concatenate(
        [
            jnp.dot(m16, kb[i], preferred_element_type=jnp.float32).astype(
                jnp.float16
            )
            for i in range(n_kv)
        ],
        axis=0,
    )

    lens = jnp.asarray(
        [jnp.int32(kv_len), jnp.int32(q_pos0)], dtype=jnp.int32
    )

    kernel = functools.partial(
        _pasa_kernel,
        block_q=block_q,
        block_kv=block_kv,
        n_kv=n_kv,
        c_eff=c_eff,
        causal=causal,
    )
    out = pl.pallas_call(
        kernel,
        grid=(s1p // block_q,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),  # lens: tiny scalar vector
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((s2p, d), lambda i: (0, 0)),  # K' resident
            pl.BlockSpec((s2p, d), lambda i: (0, 0)),  # V resident
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s1p, d), jnp.float32),
        interpret=interpret,
    )(lens, qp, kprime, vp)
    return out[:s1]
