"""Pure-jnp oracles for the attention kernels (L1 correctness signal).

`attention_ref` is the golden high-precision attention (the paper's
O_Golden in Eq. 19). `attention_fp16_partial_ref` emulates the
"partially low-precision FA (FP16-FP32)" allocation of Fig. 2 — the score
matrix is stored in float16 (the overflow site) while softmax runs in
float32. These are the baselines every Pallas kernel is tested against.
"""

import math

import jax.numpy as jnp


def attention_ref(q, k, v):
    """Standard attention, float32 throughout: softmax(QK^T/sqrt(d)) V."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    s = jnp.einsum("...qd,...kd->...qk", q, k) / math.sqrt(d)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("...qk,...kd->...qd", p, v)


def attention_ref_masked(q, k, v, kv_len=None, q_pos0=0, causal=False):
    """Golden attention with padding and causal masks.

    kv_len marks the number of valid KV rows (the rest is cache padding);
    with causal=True query row r (absolute position q_pos0 + r) attends to
    kv positions <= q_pos0 + r.
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    s1, s2 = q.shape[-2], k.shape[-2]
    s = jnp.einsum("...qd,...kd->...qk", q, k) / math.sqrt(d)
    cols = jnp.arange(s2)
    mask = jnp.ones((s1, s2), bool)
    if kv_len is not None:
        mask = mask & (cols[None, :] < kv_len)
    if causal:
        rows = jnp.arange(s1) + q_pos0
        mask = mask & (cols[None, :] <= rows[:, None])
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    # Guard fully-masked rows (all -inf) against inf - inf.
    m = jnp.maximum(m, -3.0e4)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("...qk,...kd->...qd", p / jnp.maximum(denom, 1e-30), v)


def attention_fp16_partial_ref(q, k, v):
    """Fig. 2 allocation: S stored in FP16 (overflow site), FP32 softmax.

    Reproduces the overflow -> inf -> NaN failure mode of partially
    low-precision FA on data with large bias/amplitude.
    """
    q16 = q.astype(jnp.float16)
    k16 = k.astype(jnp.float16)
    d = q.shape[-1]
    # Matrix engine: FP16 inputs, FP32 accumulate, FP16 store.
    s = jnp.einsum(
        "...qd,...kd->...qk", q16, k16, preferred_element_type=jnp.float32
    ).astype(jnp.float16)
    s = (s.astype(jnp.float32)) / math.sqrt(d)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("...qk,...kd->...qd", p, v.astype(jnp.float32))


def raw_scores(q, k):
    """S = QK^T in float32 — the paper's overflow instrumentation point."""
    return jnp.einsum(
        "...qd,...kd->...qk",
        q.astype(jnp.float32),
        k.astype(jnp.float32),
    )


def relative_rmse(computed, golden):
    """The paper's Eq. 19 metric."""
    c = jnp.asarray(computed, jnp.float64)
    g = jnp.asarray(golden, jnp.float64)
    return float(jnp.linalg.norm(c - g) / jnp.linalg.norm(g))
