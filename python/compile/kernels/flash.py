"""Flash Attention 2 Pallas kernel (L1 baseline) under the paper's
precision allocations (Figs. 1-3).

* 'fa32'    — Fig. 1: FP16 inputs, FP32 accumulate, FP32 S, FP32 softmax.
* 'fa16_32' — Fig. 2: S stored FP16 (the overflow site), FP32 softmax.
* 'fa16'    — Fig. 3: everything FP16.

Same tiling/masking structure as the PASA kernel so kernel-vs-kernel
comparisons isolate the algorithm, not the plumbing. interpret=True only
(see pasa.py).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .pasa import MASK_FLOOR, _exp16, _pad_to

ALLOCATIONS = ("fa32", "fa16_32", "fa16")


def _flash_kernel(
    lens_ref,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    *,
    block_q: int,
    block_kv: int,
    n_kv: int,
    alpha: float,
    allocation: str,
    causal: bool,
):
    kv_len = lens_ref[0]
    q_pos0 = lens_ref[1]
    score_dtype = jnp.float32 if allocation == "fa32" else jnp.float16
    vec_dtype = jnp.float16 if allocation == "fa16" else jnp.float32
    qb = q_ref[...].astype(jnp.float16)
    d = qb.shape[-1]
    rows = q_pos0 + pl.program_id(0) * block_q + jax.lax.iota(jnp.int32, block_q)
    inv_alpha = vec_dtype(1.0 / alpha)
    floor = vec_dtype(MASK_FLOOR)

    def body(j, carry):
        m, l, acc = carry
        kb = k_ref[pl.dslice(j * block_kv, block_kv), :].astype(jnp.float16)
        vb = v_ref[pl.dslice(j * block_kv, block_kv), :].astype(jnp.float16)

        # Eq. (1): S = Q K^T — FP32 accumulate; the *store* dtype is the
        # allocation's overflow decision.
        s = jnp.dot(qb, kb.T, preferred_element_type=jnp.float32).astype(score_dtype)
        # Eq. (2): static scaling (inf/alpha = inf — overflow propagates).
        s = (s.astype(vec_dtype)) * inv_alpha

        cols = j * block_kv + jax.lax.iota(jnp.int32, block_kv)
        valid = (cols < kv_len)[None, :]
        if causal:
            valid = valid & (cols[None, :] <= rows[:, None])
        s = jnp.where(valid, s, floor)

        # Eqs. (4)-(6): online softmax.
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        # exp at >= f32 internal precision (see pasa._exp16).
        p = jnp.exp((s - m_new[:, None]).astype(jnp.float32)).astype(vec_dtype)
        p = jnp.where(valid, p, vec_dtype(0.0))
        decay = jnp.exp((m - m_new).astype(jnp.float32)).astype(vec_dtype)
        l = (decay * l + jnp.sum(p, axis=1).astype(vec_dtype)).astype(vec_dtype)

        # Eq. (7): output update.
        pv = jnp.dot(
            p.astype(jnp.float16), vb, preferred_element_type=jnp.float32
        ).astype(vec_dtype)
        acc = (decay[:, None] * acc + pv).astype(vec_dtype)
        return m_new, l, acc

    m0 = jnp.full((block_q,), floor, vec_dtype)
    l0 = jnp.zeros((block_q,), vec_dtype)
    a0 = jnp.zeros((block_q, d), vec_dtype)
    _, l, acc = jax.lax.fori_loop(0, n_kv, body, (m0, l0, a0))

    # Eq. (8).
    l = jnp.maximum(l, vec_dtype(1e-30) if vec_dtype == jnp.float32 else vec_dtype(6e-8))
    o_ref[...] = (acc / l[:, None]).astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("allocation", "block_q", "block_kv", "causal", "interpret"),
)
def flash_attention(
    q,
    k,
    v,
    kv_len=None,
    q_pos0=0,
    *,
    allocation: str = "fa32",
    block_q: int = 128,
    block_kv: int = 128,
    causal: bool = False,
    interpret: bool = True,
):
    """FA2 over one head: q (S1, d), k/v (S2, d) -> (S1, d) float32."""
    assert allocation in ALLOCATIONS, allocation
    s1, d = q.shape
    s2 = k.shape[0]
    alpha = float(np.sqrt(d))
    if kv_len is None:
        kv_len = s2

    s1p = max(block_q, ((s1 + block_q - 1) // block_q) * block_q)
    s2p = max(block_kv, ((s2 + block_kv - 1) // block_kv) * block_kv)

    qp = _pad_to(q.astype(jnp.float16), s1p, 0)
    kp = _pad_to(k.astype(jnp.float16), s2p, 0)
    vp = _pad_to(v.astype(jnp.float16), s2p, 0)
    lens = jnp.asarray([jnp.int32(kv_len), jnp.int32(q_pos0)], dtype=jnp.int32)

    kernel = functools.partial(
        _flash_kernel,
        block_q=block_q,
        block_kv=block_kv,
        n_kv=s2p // block_kv,
        alpha=alpha,
        allocation=allocation,
        causal=causal,
    )
    out = pl.pallas_call(
        kernel,
        grid=(s1p // block_q,),
        in_specs=[
            pl.BlockSpec(memory_space=pl.ANY),
            pl.BlockSpec((block_q, d), lambda i: (i, 0)),
            pl.BlockSpec((s2p, d), lambda i: (0, 0)),
            pl.BlockSpec((s2p, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_q, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s1p, d), jnp.float32),
        interpret=interpret,
    )(lens, qp, kp, vp)
    return out[:s1]
