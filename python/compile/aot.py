"""AOT pipeline (L2 -> runtime): lower the model to HLO text artifacts.

HLO *text* is the interchange format (NOT serialized HloModuleProto):
jax >= 0.5 emits protos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Exports, per attention allocation in --allocs (default pasa + fa16_32):
  * prefill_<alloc>.hlo.txt  — batch 1, seq PREFILL_SEQ prompt processing,
  * decode_<alloc>.hlo.txt   — batch DECODE_BATCH single-token step,
  * head_<alloc>.hlo.txt     — standalone single-head attention kernel
                               (quickstart / runtime benches).
plus manifest.txt (module + parameter inventory the rust loader parses).

Python runs once at build time (`make artifacts`); the rust binary is
self-contained afterwards.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

PREFILL_SEQ = 256
DECODE_BATCH = 4
HEAD_SEQ = 512
HEAD_DIM = 128


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default HLO printer elides big
    # constant arrays as "{...}", which the runtime-side text parser would
    # silently read as garbage (PASA bakes the shifting matrix M in as an
    # f16 constant).
    return comp.as_hlo_text(True)


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def param_specs(cfg):
    shapes = M.param_shapes(cfg)
    return [spec(shapes[n]) for n in M.param_names(cfg)]


def make_prefill_fn(cfg):
    names = M.param_names(cfg)

    def fn(*args):
        params = dict(zip(names, args[: len(names)]))
        tokens, seq_len = args[len(names)], args[len(names) + 1]
        logits, kc, vc = M.prefill(params, tokens, seq_len, cfg)
        return logits, kc, vc

    return fn


def make_decode_fn(cfg):
    names = M.param_names(cfg)

    def fn(*args):
        params = dict(zip(names, args[: len(names)]))
        token, pos, kc, vc = args[len(names) : len(names) + 4]
        return M.decode_step(params, token, pos, kc, vc, cfg)

    return fn


def make_head_fn(alloc):
    """Standalone single-head attention module: (q, k, v) -> O."""
    if alloc == "pasa":
        from .kernels.pasa import pasa_attention

        def fn(q, k, v):
            return (pasa_attention(q, k, v),)

    else:
        from .kernels.flash import flash_attention

        def fn(q, k, v):
            return (flash_attention(q, k, v, allocation=alloc),)

    return fn


def export(out_dir: str, allocs):
    os.makedirs(out_dir, exist_ok=True)
    manifest = []
    base = M.ModelConfig()
    kvw = base.head_width

    for alloc in allocs:
        cfg = M.ModelConfig(**{**base.__dict__, "attention": alloc})

        # Prefill: batch 1, fixed prompt bucket.
        pf = make_prefill_fn(cfg)
        args = param_specs(cfg) + [
            spec((1, PREFILL_SEQ), jnp.int32),
            spec((1,), jnp.int32),
        ]
        text = to_hlo_text(jax.jit(pf).lower(*args))
        name = f"prefill_{alloc}"
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest.append(
            f"module {name} {name}.hlo.txt kind=prefill attention={alloc} "
            f"batch=1 seq={PREFILL_SEQ} maxseq={cfg.max_seq}"
        )
        print(f"wrote {name} ({len(text)} chars)")

        # Decode: fixed batch bucket against the full cache.
        df = make_decode_fn(cfg)
        cache = spec((cfg.n_layers, DECODE_BATCH, cfg.max_seq, kvw))
        args = param_specs(cfg) + [
            spec((DECODE_BATCH,), jnp.int32),
            spec((DECODE_BATCH,), jnp.int32),
            cache,
            cache,
        ]
        text = to_hlo_text(jax.jit(df).lower(*args))
        name = f"decode_{alloc}"
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest.append(
            f"module {name} {name}.hlo.txt kind=decode attention={alloc} "
            f"batch={DECODE_BATCH} maxseq={cfg.max_seq}"
        )
        print(f"wrote {name} ({len(text)} chars)")

        # Standalone head kernel.
        hf = make_head_fn(alloc)
        args = [spec((HEAD_SEQ, HEAD_DIM))] * 3
        text = to_hlo_text(jax.jit(hf).lower(*args))
        name = f"head_{alloc}"
        with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
            f.write(text)
        manifest.append(
            f"module {name} {name}.hlo.txt kind=head attention={alloc} "
            f"seq={HEAD_SEQ} dim={HEAD_DIM}"
        )
        print(f"wrote {name} ({len(text)} chars)")

    # Parameter + config inventory (the rust loader's contract).
    shapes = M.param_shapes(base)
    for n in M.param_names(base):
        dims = "x".join(str(d) for d in shapes[n]) or "scalar"
        manifest.append(f"param {n} {dims}")
    manifest.append(
        "config "
        f"vocab_size={base.vocab_size} d_model={base.d_model} "
        f"n_layers={base.n_layers} n_heads={base.n_heads} "
        f"d_head={base.d_head} d_ff={base.d_ff} max_seq={base.max_seq} "
        f"prefill_seq={PREFILL_SEQ} decode_batch={DECODE_BATCH} "
        f"pad={M.PAD} bos={M.BOS} eos={M.EOS}"
    )
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote manifest ({len(manifest)} entries)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--allocs", default="pasa,fa16_32,fa32", help="comma-separated allocations"
    )
    args = ap.parse_args()
    export(args.out, [a for a in args.allocs.split(",") if a])


if __name__ == "__main__":
    main()
