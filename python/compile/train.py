"""Train the small serving model on a synthetic corpus (build-time only).

The E2E serving validation (examples/serve_e2e.rs) needs *real* weights so
greedy decodes are meaningful text, not noise. We train the L2 transformer
briefly on a deterministic synthetic corpus of templated sentences
(counting, arithmetic, key-value recall) — enough structure for the loss
to drop sharply and for generations to be visibly patterned.

Training uses the differentiable 'ref' attention; serving uses the same
weights through the PASA / FA Pallas kernels (the paper's setting: a model
trained in high precision, served with low-precision attention).

Usage: python -m compile.train --steps 300 --out ../artifacts
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M

WORDS = "zero one two three four five six seven eight nine".split()


def synthetic_corpus(n_lines: int, seed: int = 0):
    """Deterministic templated sentences."""
    rng = np.random.default_rng(seed)
    lines = []
    for _ in range(n_lines):
        kind = rng.integers(0, 3)
        if kind == 0:
            a = int(rng.integers(0, 6))
            seq = " ".join(WORDS[a : a + 4])
            lines.append(f"count up: {seq}.")
        elif kind == 1:
            a, b = int(rng.integers(0, 5)), int(rng.integers(0, 5))
            lines.append(f"math: {a} plus {b} equals {a + b}.")
        else:
            k = WORDS[int(rng.integers(0, 10))]
            v = WORDS[int(rng.integers(0, 10))]
            lines.append(f"recall {k} maps to {v}; query {k} gives {v}.")
    return lines


def batches(lines, batch: int, seq: int, seed: int = 1):
    rng = np.random.default_rng(seed)
    ids = [M.encode_text(t, seq + 1)[0] for t in lines]
    lens = [M.encode_text(t, seq + 1)[1] for t in lines]
    ids = np.stack(ids)
    lens = np.asarray(lens)
    while True:
        sel = rng.integers(0, len(ids), batch)
        yield ids[sel], lens[sel]


def loss_fn(params, tokens, lens, cfg):
    x = tokens[:, :-1]
    y = tokens[:, 1:]
    seq_len = jnp.minimum(lens, x.shape[1]).astype(jnp.int32)
    logits, _, _ = M.prefill(params, x, seq_len, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, :, None], axis=-1)[:, :, 0]
    mask = (jnp.arange(x.shape[1])[None, :] < (lens[:, None] - 1)) & (y != M.PAD)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def adam_update(params, grads, mstate, vstate, step, lr=3e-3, b1=0.9, b2=0.999):
    out_p, out_m, out_v = {}, {}, {}
    t = step + 1
    for k in params:
        m = b1 * mstate[k] + (1 - b1) * grads[k]
        v = b2 * vstate[k] + (1 - b2) * grads[k] ** 2
        mhat = m / (1 - b1**t)
        vhat = v / (1 - b2**t)
        out_p[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + 1e-8)
        out_m[k] = m
        out_v[k] = v
    return out_p, out_m, out_v


def train(cfg: M.ModelConfig, steps: int, batch: int, seq: int, seed: int = 0):
    """Returns (params, loss_curve)."""
    tcfg = M.ModelConfig(
        **{**cfg.__dict__, "attention": "ref"}
    )  # differentiable attention for training
    params = M.init_params(jax.random.PRNGKey(seed), tcfg)
    mstate = {k: jnp.zeros_like(v) for k, v in params.items()}
    vstate = {k: jnp.zeros_like(v) for k, v in params.items()}
    gen = batches(synthetic_corpus(4000), batch, seq)

    @jax.jit
    def step_fn(params, mstate, vstate, step, tokens, lens):
        loss, grads = jax.value_and_grad(loss_fn)(params, tokens, lens, tcfg)
        params, mstate, vstate = adam_update(params, grads, mstate, vstate, step)
        return params, mstate, vstate, loss

    curve = []
    t0 = time.time()
    for i in range(steps):
        tokens, lens = next(gen)
        params, mstate, vstate, loss = step_fn(
            params, mstate, vstate, i, jnp.asarray(tokens), jnp.asarray(lens)
        )
        if i % 10 == 0 or i == steps - 1:
            curve.append((i, float(loss)))
            print(f"step {i:4d}  loss {float(loss):.4f}  ({time.time()-t0:.1f}s)")
    return params, curve


def save_weights(path: str, params, cfg: M.ModelConfig):
    """weights.bin: the rust loader's format (see rust/src/model/weights.rs).

    Layout: magic 'PASAW001', u32 n; per param (in param_names order):
    u32 name_len, name, u32 ndim, u32 dims..., f32 data (LE).
    """
    names = M.param_names(cfg)
    with open(path, "wb") as f:
        f.write(b"PASAW001")
        f.write(np.uint32(len(names)).tobytes())
        for n in names:
            arr = np.asarray(params[n], np.float32)
            nb = n.encode()
            f.write(np.uint32(len(nb)).tobytes())
            f.write(nb)
            f.write(np.uint32(arr.ndim).tobytes())
            f.write(np.asarray(arr.shape, np.uint32).tobytes())
            f.write(arr.astype("<f4").tobytes())


def load_weights(path: str):
    """Inverse of save_weights (used by aot.py and tests)."""
    params = {}
    with open(path, "rb") as f:
        assert f.read(8) == b"PASAW001", "bad weights magic"
        n = int(np.frombuffer(f.read(4), np.uint32)[0])
        for _ in range(n):
            ln = int(np.frombuffer(f.read(4), np.uint32)[0])
            name = f.read(ln).decode()
            nd = int(np.frombuffer(f.read(4), np.uint32)[0])
            dims = np.frombuffer(f.read(4 * nd), np.uint32).astype(int)
            cnt = int(np.prod(dims)) if nd else 1
            data = np.frombuffer(f.read(4 * cnt), "<f4").reshape(dims)
            params[name] = jnp.asarray(data)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=96)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()

    cfg = M.ModelConfig()
    params, curve = train(cfg, args.steps, args.batch, args.seq)
    os.makedirs(args.out, exist_ok=True)
    save_weights(os.path.join(args.out, "weights.bin"), params, cfg)
    with open(os.path.join(args.out, "loss_curve.txt"), "w") as f:
        f.write("step\tloss\n")
        for s, l in curve:
            f.write(f"{s}\t{l:.6f}\n")
    print(f"saved weights + loss curve to {args.out}")


if __name__ == "__main__":
    main()
