"""Optimal accuracy condition for beta — the paper's Appendix C code,
ported from torch to numpy (same fixed-point iteration, Eq. 22).

    beta/(1-beta) = f(beta),  f(beta) = b*n/(a*(a-b*n)) + (1-a)/a
    b = fl_tp(beta/n),        a = fl_tp(1 - beta/n) + b

Run `python -m compile.optimal_para` to print the paper's Table 3 inputs:
initial betas 1-2^-4, 1-2^-5, 1-2^-6 at n=128 solve to
0.937500, 0.968994, 0.984497.
"""

import numpy as np


def obtain_inv_pam(beta0: float, n: int, tp=np.float16, cp=np.float64) -> float:
    """The practical invariant Inva1 under tp rounding (Eq. 20/21)."""
    m0 = cp(1.0) - cp(beta0) / cp(n)
    m1 = -cp(beta0) / cp(n)
    m0 = tp(m0)  # fl_tp(1 - beta/n)
    m1 = tp(m1)  # fl_tp(-beta/n)
    b = cp(-m1)
    a = cp(m0) + b
    return float(b * n / (a * (a - b * n)) + (1.0 - a) / a)


def optimal_beta(beta0: float, n: int, tol=1e-8, tp=np.float16, cp=np.float64) -> float:
    """Fixed-point iteration beta_{k+1} = f(beta_k)/(1 + f(beta_k)) (Eq. 22)."""
    err = 1.0
    it = 0
    while err > tol and it < 500:
        inv = obtain_inv_pam(beta0, n, tp, cp)
        beta = inv / (1.0 + inv)
        err = abs(beta - beta0) / abs(beta0)
        beta0 = beta
        it += 1
    return beta0


def main():
    print("======float16 (n=128)======")
    print("Initial beta = 1-1/2**4, 1-1/2**5, 1-1/2**6")
    beta0 = [1.0 - 1.0 / 2 ** (i + 4) for i in range(3)]
    betas = [optimal_beta(b, 128) for b in beta0]
    print(f"for float16, initial beta: {beta0}")
    print(f"for float16, beta: {betas}")


if __name__ == "__main__":
    main()
