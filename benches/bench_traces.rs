//! Regenerates the model-trace cloud maps (Figs. 11–14) and times the
//! trace generation + scoring pipeline.

use pasa::bench::{emit_json, smoke, Bencher};
use pasa::experiments::{self, ExpOptions};

fn main() {
    let opts = ExpOptions {
        trace_scale: if smoke() { 32 } else { 8 },
        ..Default::default()
    };
    let b = Bencher::for_env(Bencher::quick());
    let ids: &[&str] = if smoke() {
        &["fig11"]
    } else {
        &["fig11", "fig12", "fig13", "fig14", "fig5", "fig6", "fig7"]
    };
    for id in ids {
        let mut out = String::new();
        let r = b.run(id, 1.0, || {
            out = experiments::run(id, &opts).unwrap();
        });
        println!("{out}");
        println!("{r}\n");
    }
    emit_json("bench_traces");
}
