//! Regenerates the model-trace cloud maps (Figs. 11–14) and times the
//! trace generation + scoring pipeline.

use pasa::bench::Bencher;
use pasa::experiments::{self, ExpOptions};

fn main() {
    let opts = ExpOptions {
        trace_scale: 8,
        ..Default::default()
    };
    let b = Bencher::quick();
    for id in ["fig11", "fig12", "fig13", "fig14", "fig5", "fig6", "fig7"] {
        let mut out = String::new();
        let r = b.run(id, 1.0, || {
            out = experiments::run(id, &opts).unwrap();
        });
        println!("{out}");
        println!("{r}\n");
    }
}
