//! Paged-decode benchmark (PR 2 tentpole): decode-shaped attention
//! (`s1 = 1` GQA query against a growing KV) through `KvView::Paged`
//! versus the legacy dense path — `fill_dense` into a `(max_seq, W)`
//! staging buffer, per-head column slicing, then the same kernels.
//!
//! The claim to demonstrate: paged decode cost scales with `len_tokens`
//! (tokens actually generated), while the dense path pays `O(max_seq)`
//! assembly every step regardless of how short the sequence is. Expect
//! the dense column to stay roughly flat (dominated by the 4096-row
//! staging buffer) and the paged column to shrink proportionally with
//! `len`.

use pasa::attention::{Allocation, AttentionRequest, AttnMask, KvPair, KvView};
use pasa::bench::{emit_json, smoke, Bencher};
use pasa::coordinator::{KvPool, SeqCache};
use pasa::tensor::Matrix;
use pasa::workloads::{gen_paged_decode_case, Distribution, MultiHeadCase};

const N_HEADS: usize = 8;
const N_KV: usize = 2;
const D: usize = 64;
const PAGE_TOKENS: usize = 64;

fn query_request(mh: &MultiHeadCase, alloc: Allocation, mask: AttnMask) -> AttentionRequest {
    let mut req = AttentionRequest::new(alloc).with_mask(mask).with_blocks(128, 128);
    for q in &mh.q {
        req = req.with_query_head(q.clone());
    }
    req
}

fn main() {
    let b = Bencher::for_env(Bencher::quick());
    let max_seq: usize = if smoke() { 256 } else { 4096 };
    let lens: &[usize] = if smoke() { &[128] } else { &[256, 1024, 4096] };
    let w = N_KV * D;
    println!(
        "# bench_paged_decode — decode step (s1=1, {N_HEADS}q/{N_KV}kv, d={D}) \
         at max_seq={max_seq}\n"
    );
    let dist = Distribution::Uniform { x0: 0.5, am: 1.0 };

    for alloc in [Allocation::Fa16_32, Allocation::Pasa16, Allocation::Pasa8] {
        println!("## {}", alloc.name());
        for &len in lens {
            let mh = gen_paged_decode_case(dist, N_HEADS, N_KV, len, max_seq, D, len as u64);
            // Seed only the valid prefix into the paged pool (the engine
            // never materializes rows it hasn't generated).
            let pages = 2 * max_seq.div_ceil(PAGE_TOKENS) + 4;
            let mut pool = KvPool::new(pages, PAGE_TOKENS, w);
            let mut cache = SeqCache::new(1);
            cache.ensure_capacity(&mut pool, len).unwrap();
            let (kp, vp) = mh.packed_kv_rows();
            for r in 0..len {
                cache.write_row(&mut pool, 0, r, kp.row(r), vp.row(r)).unwrap();
            }

            // Paged: gather O(len) rows page-by-page, no staging buffer.
            let req = query_request(&mh, alloc, AttnMask::Padded(vec![len]));
            let shape = format!("len{len}/max{max_seq}");
            let r = b.run_tagged(&format!("paged  len={len:>5}"), &shape, alloc.name(), len as f64, || {
                let pairs: Vec<KvPair<'_>> = (0..N_KV)
                    .map(|j| KvPair {
                        k: KvView::paged(cache.page_ids(0, false), &pool, len)
                            .col_window(j * D, D),
                        v: KvView::paged(cache.page_ids(0, true), &pool, len)
                            .col_window(j * D, D),
                    })
                    .collect();
                req.run_with_kv(&pairs).heads[0].data[0]
            });
            println!("{r}");

            // Dense: the legacy per-step path — fill_dense into the full
            // (max_seq, W) staging buffer (reused across steps, like the
            // engine's kbatch/vbatch), slice per head, run the same
            // kernels. No extra copies beyond what that path really pays.
            let mut kd = Matrix::zeros(max_seq, w);
            let mut vd = Matrix::zeros(max_seq, w);
            let r = b.run_tagged(&format!("dense  len={len:>5}"), &shape, alloc.name(), len as f64, || {
                cache.fill_dense(&pool, 0, false, &mut kd.data).unwrap();
                cache.fill_dense(&pool, 0, true, &mut vd.data).unwrap();
                let k_heads: Vec<Matrix> =
                    (0..N_KV).map(|j| kd.cols_slice(j * D, (j + 1) * D)).collect();
                let v_heads: Vec<Matrix> =
                    (0..N_KV).map(|j| vd.cols_slice(j * D, (j + 1) * D)).collect();
                let pairs: Vec<KvPair<'_>> = k_heads
                    .iter()
                    .zip(&v_heads)
                    .map(|(kh, vh)| KvPair {
                        k: KvView::Dense(kh),
                        v: KvView::Dense(vh),
                    })
                    .collect();
                req.run_with_kv(&pairs).heads[0].data[0]
            });
            println!("{r}");
        }
        println!();
    }
    println!(
        "(paged time should track len; dense time is pinned near the \
         max_seq={max_seq} assembly cost)"
    );
    emit_json("bench_paged_decode");
}
