//! Regenerates Table 3 (optimal accuracy condition) and times the
//! fixed-point solver across block widths.

use pasa::attention::beta;
use pasa::bench::{emit_json, smoke, Bencher};
use pasa::experiments::{self, ExpOptions};
use pasa::numerics::Format;

fn main() {
    if !smoke() {
        println!("{}", experiments::run("table3", &ExpOptions::default()).unwrap());
    }
    let b = Bencher::for_env(Bencher::default());
    let widths: &[usize] = if smoke() { &[128] } else { &[32, 64, 128, 256, 512] };
    for &n in widths {
        let r = b.run(&format!("solve_optimal_beta n={n}"), 1.0, || {
            beta::solve_optimal_beta(1.0 - 2f64.powi(-6), n, Format::F16, 1e-10, 500)
        });
        println!("{r}");
    }
    emit_json("bench_table3");
}
