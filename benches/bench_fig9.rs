//! Regenerates the paper's Fig. 9 (uniform-distribution RMSE sweeps) and
//! times the harness. The printed rows are the figure's series.

use pasa::bench::Bencher;
use pasa::experiments::{self, ExpOptions};

fn main() {
    let opts = ExpOptions {
        heads: 2,
        seq: 640,
        ..Default::default()
    };
    let b = Bencher::quick();
    for id in ["fig9a", "fig9b"] {
        let mut out = String::new();
        let r = b.run(id, 1.0, || {
            out = experiments::run(id, &opts).unwrap();
        });
        println!("{out}");
        println!("{r}\n");
    }
}
