//! Regenerates the paper's Fig. 9 (uniform-distribution RMSE sweeps) and
//! times the harness. The printed rows are the figure's series.

use pasa::bench::{emit_json, smoke, Bencher};
use pasa::experiments::{self, ExpOptions};

fn main() {
    let opts = ExpOptions {
        heads: 2,
        seq: if smoke() { 128 } else { 640 },
        ..Default::default()
    };
    let b = Bencher::for_env(Bencher::quick());
    let ids: &[&str] = if smoke() { &["fig9a"] } else { &["fig9a", "fig9b"] };
    for id in ids {
        let mut out = String::new();
        let r = b.run(id, 1.0, || {
            out = experiments::run(id, &opts).unwrap();
        });
        println!("{out}");
        println!("{r}\n");
    }
    emit_json("bench_fig9");
}
