//! Regenerates the paper's Fig. 10 (hybrid-distribution RMSE sweeps).

use pasa::bench::{emit_json, smoke, Bencher};
use pasa::experiments::{self, ExpOptions};

fn main() {
    let opts = ExpOptions {
        heads: 2,
        seq: if smoke() { 128 } else { 640 },
        ..Default::default()
    };
    let b = Bencher::for_env(Bencher::quick());
    let ids: &[&str] = if smoke() { &["fig10a"] } else { &["fig10a", "fig10b"] };
    for id in ids {
        let mut out = String::new();
        let r = b.run(id, 1.0, || {
            out = experiments::run(id, &opts).unwrap();
        });
        println!("{out}");
        println!("{r}\n");
    }
    emit_json("bench_fig10");
}
