//! Regenerates the paper's Fig. 10 (hybrid-distribution RMSE sweeps).

use pasa::bench::Bencher;
use pasa::experiments::{self, ExpOptions};

fn main() {
    let opts = ExpOptions {
        heads: 2,
        seq: 640,
        ..Default::default()
    };
    let b = Bencher::quick();
    for id in ["fig10a", "fig10b"] {
        let mut out = String::new();
        let r = b.run(id, 1.0, || {
            out = experiments::run(id, &opts).unwrap();
        });
        println!("{out}");
        println!("{r}\n");
    }
}
