//! Kernel-level benchmark: all precision allocations of the attention lab
//! at the paper's benchmark shape family, plus PASA's preprocessing
//! overhead (the paper's claimed-negligible batched GEMM).

use pasa::attention::{
    naive_attention_f32, run_attention, to_fp16_inputs, Allocation, AttentionConfig,
};
use pasa::bench::Bencher;
use pasa::numerics::Format;
use pasa::tensor::GemmPrecision;
use pasa::workloads::{gen_case, Distribution, Pcg64};

fn main() {
    let b = Bencher::default();
    let dist = Distribution::Uniform { x0: 5.0, am: 1.0 };
    println!("# bench_attention — lab kernels (items = attention tokens/iter)\n");

    for &(s, d) in &[(512usize, 128usize), (1280, 128)] {
        let mut rng = Pcg64::new(1, 0);
        let case = to_fp16_inputs(&gen_case(dist, s, s, d, &mut rng));
        println!("## shape ({s}, {d})");
        let r = b.run(&format!("naive f32 {s}x{d}"), s as f64, || {
            naive_attention_f32(&case)
        });
        println!("{r}");
        for alloc in Allocation::all() {
            let cfg = AttentionConfig::new(alloc);
            let r = b.run(&format!("{} {s}x{d}", alloc.name()), s as f64, || {
                run_attention(&case, &cfg)
            });
            println!("{r}");
        }
        // PASA preprocessing overhead alone: K' = M·K per 128-block.
        let m = pasa::attention::shifting_matrix(
            128,
            (d as f64).sqrt(),
            pasa::attention::PAPER_BETA,
            Format::F16,
        );
        let r = b.run(&format!("pasa preprocess K' {s}x{d}"), s as f64, || {
            let mut outs = Vec::new();
            let mut r0 = 0;
            while r0 < s {
                let r1 = (r0 + 128).min(s);
                outs.push(pasa::attention::preprocess_k(
                    &case.k.rows_slice(r0, r1),
                    &m,
                    GemmPrecision::ACC32_STORE16,
                ));
                r0 = r1;
            }
            outs
        });
        println!("{r}\n");
    }
}
