//! Kernel-level benchmark: all precision allocations of the attention lab
//! at the paper's benchmark shape family, PASA's preprocessing overhead
//! (the paper's claimed-negligible batched GEMM), the masked multi-head
//! fan-out, and the **multi-head prefill** group (heads ∈ {8, 32},
//! s ∈ {1280, 2560}) that tracks the zero-allocation + worker-pool hot
//! path against the thread-per-head/alloc-per-block baseline. Emits
//! `BENCH_bench_attention.json` alongside the stdout table;
//! `PASA_BENCH_SMOKE=1` shrinks everything to one tiny shape for CI.

use pasa::attention::{Allocation, AttentionRequest, AttnMask, KernelRegistry};
use pasa::bench::{emit_json, smoke, Bencher};
use pasa::numerics::Format;
use pasa::tensor::GemmPrecision;
use pasa::workloads::{
    gen_case, gen_multihead, gen_padded_lens, gen_padded_multihead, Distribution, Pcg64,
};

fn main() {
    let b = Bencher::for_env(Bencher::default());
    let dist = Distribution::Uniform { x0: 5.0, am: 1.0 };
    println!("# bench_attention — lab kernels (items = attention tokens/iter)\n");

    let single_shapes: &[(usize, usize)] = if smoke() { &[(64, 16)] } else { &[(512, 128), (1280, 128)] };
    for &(s, d) in single_shapes {
        let mut rng = Pcg64::new(1, 0);
        let case = gen_case(dist, s, s, d, &mut rng);
        let base = AttentionRequest::from_case(&case, Allocation::Fa32).with_fp16_inputs();
        let shape = format!("{s}x{d}");
        println!("## shape ({s}, {d})");
        let r = b.run_tagged(&format!("naive f32 {s}x{d}"), &shape, "naive-f32", s as f64, || {
            KernelRegistry::naive().forward(&base)
        });
        println!("{r}");
        for alloc in Allocation::all() {
            let req = base.clone().with_alloc(alloc);
            let r = b.run_tagged(
                &format!("{} {s}x{d}", alloc.name()),
                &shape,
                alloc.name(),
                s as f64,
                || req.run(),
            );
            println!("{r}");
        }
        // PASA preprocessing overhead alone: K' = M·K per 128-block.
        let blk = 128.min(s);
        let m = pasa::attention::shifting_matrix(
            blk,
            (d as f64).sqrt(),
            pasa::attention::PAPER_BETA,
            Format::F16,
        );
        let r = b.run_tagged(
            &format!("pasa preprocess K' {s}x{d}"),
            &shape,
            "PASA(FP16)",
            s as f64,
            || {
                let mut outs = Vec::new();
                let mut r0 = 0;
                while r0 < s {
                    let r1 = (r0 + blk).min(s);
                    outs.push(pasa::attention::preprocess_k(
                        &base.k[0].rows_slice(r0, r1),
                        &m,
                        GemmPrecision::ACC32_STORE16,
                    ));
                    r0 = r1;
                }
                outs
            },
        );
        println!("{r}\n");
    }

    // Multi-head prefill — the perf-acceptance group for the
    // zero-allocation workspace + (head × Q-block) worker-pool fan-out.
    // Compare BENCH_bench_attention.json rows across PRs at exactly these
    // shapes.
    let quick = Bencher::for_env(Bencher::quick());
    let prefill_heads: &[usize] = if smoke() { &[2] } else { &[8, 32] };
    let prefill_seqs: &[usize] = if smoke() { &[64] } else { &[1280, 2560] };
    let d = 64usize;
    println!("## multi-head prefill (d={d}, causal) — hot-path acceptance shapes");
    for &heads in prefill_heads {
        for &s in prefill_seqs {
            let mh = gen_multihead(dist, heads, s, d, 7);
            for alloc in [Allocation::Fa16_32, Allocation::Pasa16, Allocation::Pasa8] {
                let req = AttentionRequest::from_multihead(&mh, alloc)
                    .with_mask(AttnMask::Causal)
                    .with_fp16_inputs();
                let name = format!("prefill {} h={heads} s={s}", alloc.name());
                let r = quick.run_tagged(
                    &name,
                    &format!("h{heads}x{s}x{d}"),
                    alloc.name(),
                    (heads * s) as f64,
                    || req.run(),
                );
                println!("{r}");
            }
        }
    }
    println!();

    // Masked multi-head fan-out: the unified API's hot path. Causal halves
    // the visible score area, so the block-skipping tiling should land
    // meaningfully under the dense run.
    let (s, d) = if smoke() { (64usize, 16usize) } else { (256usize, 64usize) };
    println!("## masked multi-head fan-out (seq {s}, dim {d})");
    let fan_heads: &[usize] = if smoke() { &[2] } else { &[8, 32] };
    for &heads in fan_heads {
        let mh = gen_multihead(dist, heads, s, d, 2);
        for (mask, label) in [(AttnMask::None, "none"), (AttnMask::Causal, "causal")] {
            for alloc in [Allocation::Fa16_32, Allocation::Pasa16, Allocation::Pasa8] {
                let req = AttentionRequest::from_multihead(&mh, alloc)
                    .with_mask(mask.clone())
                    .with_fp16_inputs();
                let name = format!("{} h={heads} mask={label}", alloc.name());
                let r = quick.run_tagged(
                    &name,
                    &format!("h{heads}x{s}x{d} {label}"),
                    alloc.name(),
                    (heads * s) as f64,
                    || req.run(),
                );
                println!("{r}");
            }
        }
        // Right-padded batch (random valid lengths, garbage-filled
        // padding): the serving-shaped workload through the same API.
        let mut rng = Pcg64::new(3, 0);
        let lens = gen_padded_lens(heads, s, s / 4, &mut rng);
        let padded = gen_padded_multihead(dist, heads, s, d, &lens, 4);
        let req = AttentionRequest::from_multihead(&padded, Allocation::Pasa16)
            .with_fp16_inputs();
        let r = quick.run_tagged(
            &format!("{} h={heads} mask=padded", Allocation::Pasa16.name()),
            &format!("h{heads}x{s}x{d} padded"),
            Allocation::Pasa16.name(),
            (heads * s) as f64,
            || req.run(),
        );
        println!("{r}\n");
    }

    emit_json("bench_attention");
}
