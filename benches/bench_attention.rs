//! Kernel-level benchmark: all precision allocations of the attention lab
//! at the paper's benchmark shape family, PASA's preprocessing overhead
//! (the paper's claimed-negligible batched GEMM), and the multi-head
//! fan-out with masks (heads ∈ {8, 32}, causal vs none) — the perf
//! baseline for the unified AttentionKernel API.

use pasa::attention::{
    Allocation, AttentionRequest, AttnMask, KernelRegistry,
};
use pasa::bench::Bencher;
use pasa::numerics::Format;
use pasa::tensor::GemmPrecision;
use pasa::workloads::{
    gen_case, gen_multihead, gen_padded_lens, gen_padded_multihead, Distribution, Pcg64,
};

fn main() {
    let b = Bencher::default();
    let dist = Distribution::Uniform { x0: 5.0, am: 1.0 };
    println!("# bench_attention — lab kernels (items = attention tokens/iter)\n");

    for &(s, d) in &[(512usize, 128usize), (1280, 128)] {
        let mut rng = Pcg64::new(1, 0);
        let case = gen_case(dist, s, s, d, &mut rng);
        let base = AttentionRequest::from_case(&case, Allocation::Fa32).with_fp16_inputs();
        println!("## shape ({s}, {d})");
        let r = b.run(&format!("naive f32 {s}x{d}"), s as f64, || {
            KernelRegistry::naive().forward(&base)
        });
        println!("{r}");
        for alloc in Allocation::all() {
            let req = base.clone().with_alloc(alloc);
            let r = b.run(&format!("{} {s}x{d}", alloc.name()), s as f64, || req.run());
            println!("{r}");
        }
        // PASA preprocessing overhead alone: K' = M·K per 128-block.
        let m = pasa::attention::shifting_matrix(
            128,
            (d as f64).sqrt(),
            pasa::attention::PAPER_BETA,
            Format::F16,
        );
        let r = b.run(&format!("pasa preprocess K' {s}x{d}"), s as f64, || {
            let mut outs = Vec::new();
            let mut r0 = 0;
            while r0 < s {
                let r1 = (r0 + 128).min(s);
                outs.push(pasa::attention::preprocess_k(
                    &base.k[0].rows_slice(r0, r1),
                    &m,
                    GemmPrecision::ACC32_STORE16,
                ));
                r0 = r1;
            }
            outs
        });
        println!("{r}\n");
    }

    // Masked multi-head fan-out: the unified API's hot path. Causal halves
    // the visible score area, so the block-skipping tiling should land
    // meaningfully under the dense run.
    let quick = Bencher::quick();
    let (s, d) = (256usize, 64usize);
    println!("## masked multi-head fan-out (seq {s}, dim {d})");
    for &heads in &[8usize, 32] {
        let mh = gen_multihead(dist, heads, s, d, 2);
        for (mask, label) in [(AttnMask::None, "none"), (AttnMask::Causal, "causal")] {
            for alloc in [Allocation::Fa16_32, Allocation::Pasa16] {
                let req = AttentionRequest::from_multihead(&mh, alloc)
                    .with_mask(mask.clone())
                    .with_fp16_inputs();
                let name = format!("{} h={heads} mask={label}", alloc.name());
                let r = quick.run(&name, (heads * s) as f64, || req.run());
                println!("{r}");
            }
        }
        // Right-padded batch (random valid lengths, garbage-filled
        // padding): the serving-shaped workload through the same API.
        let mut rng = Pcg64::new(3, 0);
        let lens = gen_padded_lens(heads, s, s / 4, &mut rng);
        let padded = gen_padded_multihead(dist, heads, s, d, &lens, 4);
        let req = AttentionRequest::from_multihead(&padded, Allocation::Pasa16)
            .with_fp16_inputs();
        let r = quick.run(
            &format!("{} h={heads} mask=padded", Allocation::Pasa16.name()),
            (heads * s) as f64,
            || req.run(),
        );
        println!("{r}\n");
    }
}
