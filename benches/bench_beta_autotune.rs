//! β-autotune benchmark (PR 3 tentpole): the cost of the precision-policy
//! layer, measured at its three sites.
//!
//! 1. **autotune pass** — probe forward (FA16-32, the serving fast path)
//!    plus the per-head Table 3 solve: what a serving engine would pay to
//!    re-tune a request's β table from live telemetry.
//! 2. **solver alone** — `solve_optimal_beta` per head count, isolating
//!    the fixed-point iteration from the probe forward.
//! 3. **PASA forward, uniform vs per-head β** — per-head tables with one
//!    distinct β per GQA group vs one global β. The (KV head, β)-keyed
//!    preprocessing means a uniform-valued table costs exactly the shared
//!    path; distinct βs pay one extra K' = M·K GEMM per extra β.
//!
//! Run: cargo bench --bench bench_beta_autotune

use pasa::attention::{Allocation, AttentionRequest, BetaPolicy, KernelRegistry};
use pasa::bench::{emit_json, smoke, Bencher};
use pasa::numerics::Format;
use pasa::workloads::{gen_gqa_multihead, Distribution};

const SEQ: usize = 256;
const DIM: usize = 64;

fn main() {
    let b = Bencher::for_env(Bencher::quick());
    println!("# bench_beta_autotune — precision-policy layer (seq={SEQ}, d={DIM})\n");
    let dist = Distribution::Uniform { x0: 10.0, am: 1.0 };

    let head_counts: &[usize] = if smoke() { &[8] } else { &[8, 32] };
    for &heads in head_counts {
        let n_kv = heads / 4;
        let mh = gen_gqa_multihead(dist, heads, n_kv, SEQ, SEQ, DIM, heads as u64);
        let req = AttentionRequest::from_multihead(&mh, Allocation::Fa16_32).with_fp16_inputs();
        println!("## {heads} query heads / {n_kv} KV heads");

        // 1. Full autotune pass: probe + per-head solve.
        let r = b.run(&format!("autotune probe+solve h={heads:>2}"), heads as f64, || {
            let probe = req.run();
            BetaPolicy::autotune(&probe.stats, req.cfg.blocks.s2, Format::F16)
        });
        println!("{r}");

        // 2. Solver alone (per-head fixed-point iterations).
        let probe = req.run();
        let peaks: Vec<f32> = probe.stats.iter().map(|s| s.max_abs_score).collect();
        let r = b.run(&format!("solver only        h={heads:>2}"), heads as f64, || {
            pasa::attention::autotune_betas(&peaks, req.cfg.blocks.s2, Format::F16)
        });
        println!("{r}");

        // 3. PASA forward: uniform β vs a per-head table (one β per GQA
        // group — the worst case for K' sharing at this head count).
        let pasa_req = req.clone().with_alloc(Allocation::Pasa16);
        let r = b.run(&format!("pasa uniform beta  h={heads:>2}"), heads as f64, || {
            KernelRegistry::get(Allocation::Pasa16).forward(&pasa_req).heads[0].data[0]
        });
        println!("{r}");
        let grid = [0.9375, 0.968994, 0.984497];
        let betas: Vec<f64> = (0..heads).map(|h| grid[(h * n_kv / heads) % 3]).collect();
        let per_req = pasa_req.clone().with_policy(BetaPolicy::PerHead(betas));
        let r = b.run(&format!("pasa per-head beta h={heads:>2}"), heads as f64, || {
            KernelRegistry::get(Allocation::Pasa16).forward(&per_req).heads[0].data[0]
        });
        println!("{r}");
        println!();
    }
    println!(
        "(uniform-valued tables collapse to the shared-K' path; distinct βs \
         add one M·K GEMM per extra β per KV head)"
    );
    emit_json("bench_beta_autotune");
}
