//! Coordinator-substrate benchmark: paged KV pool allocate/write/assemble
//! throughput (the L3 hot path around each decode step).

use pasa::bench::{emit_json, smoke, Bencher};
use pasa::coordinator::{KvPool, SeqCache};

fn main() {
    let b = Bencher::for_env(Bencher::default());
    let (layers, width, page_tokens) = (4usize, 256usize, 32usize);
    let seq: usize = if smoke() { 64 } else { 512 };
    println!("# bench_kv_cache — paged pool ops\n");

    let r = b.run(&format!("alloc+release {seq}-token seq"), seq as f64, || {
        let mut pool = KvPool::new(1024, page_tokens, width);
        let mut s = SeqCache::new(layers);
        s.ensure_capacity(&mut pool, seq).unwrap();
        s.release(&mut pool);
        pool.used_pages()
    });
    println!("{r}");

    let mut pool = KvPool::new(4096, page_tokens, width);
    let mut s = SeqCache::new(layers);
    s.ensure_capacity(&mut pool, seq).unwrap();
    let krow = vec![1.0f32; width];
    let vrow = vec![2.0f32; width];
    let wpos = seq / 2;
    let r = b.run("write_row x 4 layers", 4.0, || {
        for l in 0..layers {
            s.write_row(&mut pool, l, wpos, &krow, &vrow).unwrap();
        }
    });
    println!("{r}");

    // The parallel-decode write path (prepared, shared-pool): must be at
    // least as cheap as the exclusive path it mirrors.
    s.prepare_step(&mut pool, wpos).unwrap();
    let r = b.run("write_row_prepared x 4 layers", 4.0, || {
        for l in 0..layers {
            s.write_row_prepared(&pool, l, wpos, &krow, &vrow);
        }
    });
    println!("{r}");

    s.len_tokens = seq;
    let mut dense = vec![0.0f32; seq * width];
    let r = b.run(&format!("fill_dense one layer ({seq} tok)"), seq as f64, || {
        s.fill_dense(&pool, 0, false, &mut dense).unwrap();
        dense[0]
    });
    println!("{r}");

    // Full batch assembly, the per-decode-step cost: B=4, K+V, all layers.
    let seqs: Vec<SeqCache> = (0..4)
        .map(|_| {
            let mut c = SeqCache::new(layers);
            c.ensure_capacity(&mut pool, seq).unwrap();
            c.len_tokens = seq * 4 / 5;
            c
        })
        .collect();
    let mut batch = vec![0.0f32; layers * 4 * seq * width];
    let r = b.run("assemble decode batch (4x4 layers, K+V)", 4.0, || {
        let sf = seq * width;
        for (i, c) in seqs.iter().enumerate() {
            for l in 0..layers {
                let off = (l * 4 + i) * sf;
                c.fill_dense(&pool, l, false, &mut batch[off..off + sf]).unwrap();
                c.fill_dense(&pool, l, true, &mut batch[off..off + sf]).unwrap();
            }
        }
        batch[0]
    });
    println!("{r}");

    emit_json("bench_kv_cache");
}
