//! Coordinator-substrate benchmark: paged KV pool allocate/write/assemble
//! throughput (the L3 hot path around each decode step).

use pasa::bench::Bencher;
use pasa::coordinator::{KvPool, SeqCache};

fn main() {
    let b = Bencher::default();
    let (layers, width, page_tokens) = (4usize, 256usize, 32usize);
    println!("# bench_kv_cache — paged pool ops\n");

    let r = b.run("alloc+release 512-token seq", 512.0, || {
        let mut pool = KvPool::new(1024, page_tokens, width);
        let mut s = SeqCache::new(layers);
        s.ensure_capacity(&mut pool, 512).unwrap();
        s.release(&mut pool);
        pool.used_pages()
    });
    println!("{r}");

    let mut pool = KvPool::new(4096, page_tokens, width);
    let mut s = SeqCache::new(layers);
    s.ensure_capacity(&mut pool, 512).unwrap();
    let krow = vec![1.0f32; width];
    let vrow = vec![2.0f32; width];
    let r = b.run("write_row x 4 layers", 4.0, || {
        for l in 0..layers {
            s.write_row(&mut pool, l, 200, &krow, &vrow).unwrap();
        }
    });
    println!("{r}");

    s.len_tokens = 512;
    let mut dense = vec![0.0f32; 512 * width];
    let r = b.run("fill_dense one layer (512 tok)", 512.0, || {
        s.fill_dense(&pool, 0, false, &mut dense).unwrap();
        dense[0]
    });
    println!("{r}");

    // Full batch assembly, the per-decode-step cost: B=4, K+V, all layers.
    let seqs: Vec<SeqCache> = (0..4)
        .map(|_| {
            let mut c = SeqCache::new(layers);
            c.ensure_capacity(&mut pool, 512).unwrap();
            c.len_tokens = 400;
            c
        })
        .collect();
    let mut batch = vec![0.0f32; layers * 4 * 512 * width];
    let r = b.run("assemble decode batch (4x4 layers, K+V)", 4.0, || {
        let sf = 512 * width;
        for (i, c) in seqs.iter().enumerate() {
            for l in 0..layers {
                let off = (l * 4 + i) * sf;
                c.fill_dense(&pool, l, false, &mut batch[off..off + sf]).unwrap();
                c.fill_dense(&pool, l, true, &mut batch[off..off + sf]).unwrap();
            }
        }
        batch[0]
    });
    println!("{r}");
}
