//! End-to-end serving benchmark.
//!
//! Part 1 (always runs, artifact-free): the continuous-batching
//! scheduler on the lab backend over seeded arrival-process traces —
//! Poisson and bursty arrivals × FIFO-compat vs token-budget scheduling
//! × prefill chunk budgets. Reports tokens/s and TTFT/ITL percentiles
//! per cell; every cell also lands in `BENCH_bench_serving.json` via the
//! tagged registry (the CI smoke job runs this with `PASA_BENCH_SMOKE=1`
//! on a trimmed trace).
//!
//! Part 2 (requires `make artifacts`): decode-step latency and tokens/s
//! per guard policy through the PJRT runtime — the paper's serving-side
//! framing (FA low-precision throughput vs robustness).

use pasa::bench::{emit_json, Bencher};
use pasa::coordinator::{
    Engine, EngineConfig, FaultPlan, FaultRates, FinishReason, GenParams, GuardPolicy, KvStore,
    Request, SchedulerConfig,
};
use pasa::model::{ModelDims, Sampling};
use pasa::runtime::{LabModel, ModelRuntime};
use pasa::workloads::{
    bursty_trace, poisson_trace, prompt_of_tokens, shared_prefix_prompt, shared_prefix_trace,
    Arrival, ArrivalShape,
};
use std::path::Path;
use std::time::Instant;

fn lab_dims() -> ModelDims {
    ModelDims {
        vocab_size: 259,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_head: 8,
        d_ff: 64,
        max_seq: 128,
        prefill_seq: 32,
        decode_batch: 4,
        pad: 256,
        bos: 257,
        eos: 258,
    }
}

/// Replay one arrival trace through a fresh lab engine: submit every
/// request whose step has come due, then run one scheduler iteration —
/// trace time is engine-step time, so the run is host-speed independent.
/// Returns (tokens generated, ttft_p50, ttft_p95, itl_p95) in seconds.
fn run_trace(sched: SchedulerConfig, trace: &[Arrival]) -> (u64, f64, f64, f64) {
    let (tokens, p50, p95, itl95, _) = run_trace_store(sched, trace, KvStore::F32, 1024);
    (tokens, p50, p95, itl95)
}

/// [`run_trace`] with an explicit KV storage format and page budget
/// (`kv_pages` is denominated in *f32* pages, so both formats get the
/// same arena bytes — E4M3 just fits 4× the pages in them). The extra
/// return is the KV-page deferral count, the admission-side fingerprint
/// of the doubled-residency effect.
fn run_trace_store(
    sched: SchedulerConfig,
    trace: &[Arrival],
    store: KvStore,
    kv_pages: usize,
) -> (u64, f64, f64, f64, u64) {
    let mut cfg = EngineConfig::default();
    cfg.policy = GuardPolicy::Adaptive;
    cfg.kv_pages = kv_pages;
    cfg.page_tokens = 16;
    cfg.max_queue = 1024;
    cfg.kv_store = store;
    cfg.sched = sched;
    let mut eng = Engine::from_lab(LabModel::synthetic(lab_dims(), 42), cfg);
    let mut next = 0usize;
    let mut step = 0usize;
    while next < trace.len() || !eng.idle() {
        while next < trace.len() && trace[next].step <= step {
            let a = trace[next];
            let id = eng.fresh_id();
            eng.submit(
                Request::new(id, prompt_of_tokens(a.prompt_tokens)).with_params(GenParams {
                    max_new_tokens: a.max_new,
                    sampling: Sampling::Greedy,
                    stop_at_eos: false,
                }),
            );
            next += 1;
        }
        eng.step().expect("lab engine step");
        step += 1;
    }
    let ttft = eng.metrics.ttft.summary();
    let itl = eng.metrics.itl.summary();
    (
        eng.metrics.tokens_generated,
        ttft.p50,
        ttft.p95,
        itl.p95,
        eng.metrics.deferrals.kv_pages,
    )
}

/// Shared-prefix cell: every request's prompt opens with the same
/// `prefix_tokens`-token span (per-request distinct tails), replayed
/// with the radix prefix cache capped at `cache_pages` (0 = off). The
/// pool is ample, so the cells differ only in prefill *work*: the on
/// cell seeds followers from shared pages and skips the page-aligned
/// span, visible as saved prefill tokens and a lower TTFT at identical
/// offered load. Returns (tokens, ttft_p50, ttft_p95, prefill tokens
/// saved, kv-page deferrals).
fn run_trace_prefix(
    sched: SchedulerConfig,
    trace: &[Arrival],
    prefix_tokens: usize,
    cache_pages: usize,
) -> (u64, f64, f64, u64, u64) {
    let mut cfg = EngineConfig::default();
    cfg.policy = GuardPolicy::Adaptive;
    cfg.kv_pages = 1024;
    cfg.page_tokens = 16;
    cfg.max_queue = 1024;
    cfg.prefix_cache_pages = cache_pages;
    cfg.sched = sched;
    let mut eng = Engine::from_lab(LabModel::synthetic(lab_dims(), 42), cfg);
    let mut next = 0usize;
    let mut step = 0usize;
    while next < trace.len() || !eng.idle() {
        while next < trace.len() && trace[next].step <= step {
            let a = trace[next];
            let id = eng.fresh_id();
            eng.submit(
                Request::new(id, shared_prefix_prompt(prefix_tokens, a.prompt_tokens, next))
                    .with_params(GenParams {
                        max_new_tokens: a.max_new,
                        sampling: Sampling::Greedy,
                        stop_at_eos: false,
                    }),
            );
            next += 1;
        }
        eng.step().expect("lab engine step");
        step += 1;
    }
    let ttft = eng.metrics.ttft.summary();
    (
        eng.metrics.tokens_generated,
        ttft.p50,
        ttft.p95,
        eng.metrics.prefix.tokens_saved,
        eng.metrics.deferrals.kv_pages,
    )
}

/// Chaos cell replay: like [`run_trace`], but with a seeded fault plan
/// installed (the same uniform per-kind rate at every seam) and **no**
/// token-conservation assert — disruption is the measurement. The pool
/// is sized so seizures genuinely evict, which is what gives the
/// retry-budget axis something to recover. Returns (tokens generated,
/// completions finished normally, completions disrupted, retries,
/// injections logged).
fn run_chaos(
    sched: SchedulerConfig,
    trace: &[Arrival],
    rate: f64,
    seed: u64,
) -> (u64, u64, u64, u64, u64) {
    let mut cfg = EngineConfig::default();
    cfg.policy = GuardPolicy::Adaptive;
    cfg.kv_pages = 160;
    cfg.page_tokens = 16;
    cfg.max_queue = 1024;
    cfg.sched = sched;
    let mut eng = Engine::from_lab(LabModel::synthetic(lab_dims(), 42), cfg);
    let mut plan = FaultPlan::new(seed, FaultRates::uniform(rate));
    plan.seize_pages = 64;
    eng.install_faults(plan);
    let mut next = 0usize;
    let mut step = 0usize;
    while next < trace.len() || !eng.idle() {
        while next < trace.len() && trace[next].step <= step {
            let a = trace[next];
            let id = eng.fresh_id();
            eng.submit(
                Request::new(id, prompt_of_tokens(a.prompt_tokens)).with_params(GenParams {
                    max_new_tokens: a.max_new,
                    sampling: Sampling::Greedy,
                    stop_at_eos: false,
                }),
            );
            next += 1;
        }
        eng.step().expect("lab engine step");
        step += 1;
        if step > 100_000 {
            break; // safety valve; chaos runs are bounded by construction
        }
    }
    let comps = eng.take_completions();
    let ok = comps
        .iter()
        .filter(|c| matches!(c.reason, FinishReason::MaxTokens | FinishReason::Eos))
        .count() as u64;
    let disrupted = comps.len() as u64 - ok;
    (
        eng.metrics.tokens_generated,
        ok,
        disrupted,
        eng.metrics.robustness.retries,
        eng.metrics.robustness.faults_total(),
    )
}

fn main() -> anyhow::Result<()> {
    // ---- Part 1: scheduler grid on the lab backend (always runs) ----
    let smoke = pasa::bench::smoke();
    let n_requests = if smoke { 12 } else { 48 };
    let shape = ArrivalShape::default();
    let arrivals: [(&str, Vec<Arrival>); 2] = [
        ("poisson-0.8", poisson_trace(n_requests, 0.8, shape, 7)),
        ("bursty-6x4", bursty_trace(n_requests, 6, 4, shape, 7)),
    ];
    let scheds: [(&str, SchedulerConfig); 3] = [
        ("fifo", SchedulerConfig::fifo_compat()),
        (
            "cont-chunk32",
            SchedulerConfig {
                max_batch_prefill_tokens: 32,
                ..SchedulerConfig::default()
            },
        ),
        (
            "cont-chunk128",
            SchedulerConfig {
                max_batch_prefill_tokens: 128,
                ..SchedulerConfig::default()
            },
        ),
    ];

    println!("# bench_serving — scheduler grid, lab backend ({n_requests} requests/cell)\n");
    let b = Bencher::for_env(Bencher::quick());
    for (aname, trace) in &arrivals {
        let offered: u64 = trace.iter().map(|a| a.max_new as u64).sum();
        for (sname, sched) in &scheds {
            let (tokens, p50, p95, itl95) = run_trace(*sched, trace);
            assert_eq!(tokens, offered, "scheduler dropped tokens");
            let r = b.run_tagged(
                &format!("serve {aname} {sname}"),
                aname,
                sname,
                tokens as f64,
                || run_trace(*sched, trace),
            );
            println!(
                "{aname:<12} {sname:<14} ttft_p50={:>8.4}s ttft_p95={:>8.4}s itl_p95={:>8.4}s  {r}",
                p50, p95, itl95
            );
        }
    }

    // ---- Part 1b: KV storage format at a fixed byte budget ----
    // Bursty replay with a fixed 12+4-token request shape: each request
    // commits exactly one page per K/V chain (16 tokens at 16
    // tokens/page), all of it allocated by the first prefill chunk — so
    // the admission page check is exact and no slot can ever be evicted
    // by lazy growth. At 16 f32-denominated pages both cells hold the
    // *same arena bytes*: the f32 pool seats 4 sequences, the E4M3 pool
    // (4× the pages in the same bytes) seats every burst whole — visible
    // as fewer KV-page deferrals and a lower tail TTFT at identical
    // offered load. The slot cap is lifted to 16 so page capacity, not
    // batch width, binds.
    println!("\n# bench_serving — KV storage format, fixed byte budget (bursty-6x4)\n");
    let kv_shape = ArrivalShape {
        min_prompt_tokens: 12,
        max_prompt_tokens: 12,
        min_new: 4,
        max_new: 4,
    };
    let kv_trace = bursty_trace(n_requests, 6, 4, kv_shape, 7);
    let kv_sched = SchedulerConfig {
        max_batch_size: 16,
        ..SchedulerConfig::default()
    };
    for (kname, store) in [("kv-f32", KvStore::F32), ("kv-e4m3", KvStore::E4m3)] {
        let offered: u64 = kv_trace.iter().map(|a| a.max_new as u64).sum();
        let (tokens, p50, p95, itl95, defers) = run_trace_store(kv_sched, &kv_trace, store, 16);
        assert_eq!(tokens, offered, "kv-store cell dropped tokens");
        let r = b.run_tagged(
            &format!("serve bursty-6x4 {kname}"),
            "bursty-6x4",
            kname,
            tokens as f64,
            || run_trace_store(kv_sched, &kv_trace, store, 16),
        );
        println!(
            "{kname:<12} ttft_p50={p50:>8.4}s ttft_p95={p95:>8.4}s itl_p95={itl95:>8.4}s \
             kv_deferrals={defers:<5} {r}"
        );
    }

    // ---- Part 1c: prefix cache on a shared-prefix workload ----
    // A fleet sharing a 48-token system prompt (3 pages at 16
    // tokens/page) with per-request tails. Cache off vs on at the same
    // offered load: the on cell must generate the same token count
    // while skipping the shared span's prefill for every follower hit.
    println!("\n# bench_serving — shared-prefix workload, prefix cache off vs on\n");
    let px_tokens = 48usize;
    let px_shape = ArrivalShape {
        min_prompt_tokens: 52,
        max_prompt_tokens: 64,
        min_new: 4,
        max_new: 12,
    };
    let px_trace = shared_prefix_trace(n_requests, 0.8, px_tokens, px_shape, 7);
    let px_offered: u64 = px_trace.iter().map(|a| a.max_new as u64).sum();
    for (pname, cache_pages) in [("prefix-off", 0usize), ("prefix-on", 64)] {
        let (tokens, p50, p95, saved, defers) =
            run_trace_prefix(SchedulerConfig::default(), &px_trace, px_tokens, cache_pages);
        assert_eq!(tokens, px_offered, "prefix cell dropped tokens");
        if cache_pages > 0 {
            assert!(saved > 0, "the shared-prefix trace never hit the cache");
        } else {
            assert_eq!(saved, 0, "cache off must save nothing");
        }
        let r = b.run_tagged(
            &format!("serve shared-prefix {pname}"),
            "shared-prefix",
            pname,
            tokens as f64,
            || run_trace_prefix(SchedulerConfig::default(), &px_trace, px_tokens, cache_pages),
        );
        println!(
            "{pname:<12} ttft_p50={p50:>8.4}s ttft_p95={p95:>8.4}s \
             prefill_saved={saved:<6} kv_deferrals={defers:<5} {r}"
        );
    }

    // ---- Part 1d: chaos grid — fault rate × retry budget ----
    // How throughput and completion quality degrade under injected
    // faults, and how much of the loss a retry budget claws back. The
    // fault-0 row is the control: a zero-rate plan consumes no
    // randomness, so it must match the fault-free scheduler exactly.
    println!("\n# bench_serving — chaos grid (poisson-0.8, fault-rate x retry-budget)\n");
    let chaos_trace = poisson_trace(n_requests, 0.8, shape, 11);
    for &(rname, rate) in &[("fault-0", 0.0), ("fault-2pct", 0.02), ("fault-8pct", 0.08)] {
        for &(bname, budget) in &[("retry-0", 0usize), ("retry-2", 2)] {
            let sched = SchedulerConfig {
                retry_budget: budget,
                ..SchedulerConfig::default()
            };
            let (tokens, ok, disrupted, retries, injections) =
                run_chaos(sched, &chaos_trace, rate, 0xC4A05);
            let r = b.run_tagged(
                &format!("serve chaos {rname} {bname}"),
                rname,
                bname,
                tokens as f64,
                || run_chaos(sched, &chaos_trace, rate, 0xC4A05),
            );
            println!(
                "{rname:<12} {bname:<10} ok={ok:<3} disrupted={disrupted:<3} \
                 retries={retries:<3} injections={injections:<4} {r}"
            );
        }
    }

    // ---- Part 2: PJRT policy sweep (needs compiled artifacts) ----
    let art = Path::new("artifacts");
    if !art.join("manifest.txt").exists() {
        println!("\nartifacts/ missing — run `make artifacts`; skipping the PJRT sweep");
        emit_json("bench_serving");
        return Ok(());
    }
    let rt = ModelRuntime::load(art)?;
    println!("\n# bench_serving — full stack over {:?}\n", rt.dims);

    for policy in [
        GuardPolicy::AlwaysFa16,
        GuardPolicy::AlwaysPasa,
        GuardPolicy::AlwaysFa32,
        GuardPolicy::Adaptive,
    ] {
        let mut cfg = EngineConfig::default();
        cfg.policy = policy;
        let mut eng = Engine::new(&rt, cfg);
        for i in 0..8 {
            let id = eng.fresh_id();
            eng.submit(Request::new(id, format!("count up: {}", ["one","two","three","four"][i % 4]))
                .with_params(GenParams {
                    max_new_tokens: 24,
                    sampling: Sampling::Greedy,
                    stop_at_eos: false,
                }));
        }
        let t0 = Instant::now();
        eng.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<24} tok/s={:>7.1}  step_mean={:>7.2}ms  ttft_p95={:>7.2}ms  wall={:.2}s",
            format!("{policy:?}"),
            eng.metrics.tokens_generated as f64 / wall,
            eng.metrics.step_latency.mean() * 1e3,
            eng.metrics.ttft.percentile(95.0) * 1e3,
            wall
        );
    }

    // Raw decode-step latency through the head kernels.
    let b = Bencher::for_env(Bencher::quick());
    let n = 512 * 128;
    let q = vec![0.1f32; n];
    let k = vec![0.2f32; n];
    let v = vec![0.3f32; n];
    for alloc in ["pasa", "fa16_32", "fa32"] {
        let r = b.run_tagged(&format!("head kernel {alloc} (512x128)"), "512x128", alloc, 512.0, || {
            rt.head(alloc, &q, &k, &v).unwrap()
        });
        println!("{r}");
    }
    emit_json("bench_serving");
    Ok(())
}
