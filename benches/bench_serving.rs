//! End-to-end serving benchmark (requires `make artifacts`): decode-step
//! latency and tokens/s per guard policy — the paper's serving-side
//! framing (FA low-precision throughput vs robustness).

use pasa::bench::{emit_json, Bencher};
use pasa::coordinator::{Engine, EngineConfig, GenParams, GuardPolicy, Request};
use pasa::model::Sampling;
use pasa::runtime::ModelRuntime;
use std::path::Path;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let art = Path::new("artifacts");
    if !art.join("manifest.txt").exists() {
        println!("artifacts/ missing — run `make artifacts`; skipping bench_serving");
        emit_json("bench_serving");
        return Ok(());
    }
    let rt = ModelRuntime::load(art)?;
    println!("# bench_serving — full stack over {:?}\n", rt.dims);

    for policy in [
        GuardPolicy::AlwaysFa16,
        GuardPolicy::AlwaysPasa,
        GuardPolicy::AlwaysFa32,
        GuardPolicy::Adaptive,
    ] {
        let mut cfg = EngineConfig::default();
        cfg.policy = policy;
        let mut eng = Engine::new(&rt, cfg);
        for i in 0..8 {
            let id = eng.fresh_id();
            eng.submit(Request::new(id, format!("count up: {}", ["one","two","three","four"][i % 4]))
                .with_params(GenParams {
                    max_new_tokens: 24,
                    sampling: Sampling::Greedy,
                    stop_at_eos: false,
                }));
        }
        let t0 = Instant::now();
        eng.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "{:<24} tok/s={:>7.1}  step_mean={:>7.2}ms  ttft_p95={:>7.2}ms  wall={:.2}s",
            format!("{policy:?}"),
            eng.metrics.tokens_generated as f64 / wall,
            eng.metrics.step_latency.mean() * 1e3,
            eng.metrics.ttft.percentile(95.0) * 1e3,
            wall
        );
    }

    // Raw decode-step latency through the head kernels.
    let b = Bencher::for_env(Bencher::quick());
    let n = 512 * 128;
    let q = vec![0.1f32; n];
    let k = vec![0.2f32; n];
    let v = vec![0.3f32; n];
    for alloc in ["pasa", "fa16_32", "fa32"] {
        let r = b.run_tagged(&format!("head kernel {alloc} (512x128)"), "512x128", alloc, 512.0, || {
            rt.head(alloc, &q, &k, &v).unwrap()
        });
        println!("{r}");
    }
    emit_json("bench_serving");
    Ok(())
}
