//! Regenerates Table 4 (NaN percentages) and times the harness.

use pasa::bench::Bencher;
use pasa::experiments::{self, ExpOptions};

fn main() {
    let opts = ExpOptions {
        heads: 2,
        seq: 640,
        ..Default::default()
    };
    let b = Bencher::quick();
    let mut out = String::new();
    let r = b.run("table4", 1.0, || {
        out = experiments::run("table4", &opts).unwrap();
    });
    println!("{out}");
    println!("{r}");
}
