//! Regenerates Table 4 (NaN percentages) and times the harness.

use pasa::bench::{emit_json, smoke, Bencher};
use pasa::experiments::{self, ExpOptions};

fn main() {
    let opts = ExpOptions {
        heads: 2,
        seq: if smoke() { 128 } else { 640 },
        ..Default::default()
    };
    let b = Bencher::for_env(Bencher::quick());
    let mut out = String::new();
    let r = b.run("table4", 1.0, || {
        out = experiments::run("table4", &opts).unwrap();
    });
    println!("{out}");
    println!("{r}");
    emit_json("bench_table4");
}
