//! Numerical accuracy study — regenerates the paper's Figs. 9–10 RMSE
//! sweeps and Table 4 at a configurable size.
//!
//! Run: cargo run --release --example rmse_study
//! (paper-fidelity size: pasa repro --exp fig9a --heads 16 --seq 1280)

use pasa::experiments::{self, ExpOptions};

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions {
        heads: 2,
        seq: 640,
        dim: 128,
        trace_scale: 8,
        seed: 42,
    };
    for id in ["fig9a", "fig9b", "fig10a", "fig10b", "table4"] {
        println!("{}", experiments::run(id, &opts)?);
    }
    println!("rmse_study OK (reduced size; use the `pasa repro` CLI for paper-scale runs)");
    Ok(())
}
