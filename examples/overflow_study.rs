//! Overflow mechanism study (paper §3.3.2, Figs. 6–7, 11–14).
//!
//! 1. Generates the Qwen2 / SVD-shaped synthetic overflow traces (the
//!    resonance + sequence-bias mechanism the paper identifies) and shows
//!    the raw scores overflowing FP16 while PASA's shifted scores fit.
//! 2. Demonstrates both resonance categories (Fig. 6).
//! 3. Pushes a resonant case through the *runtime* head kernels (PJRT):
//!    the FA(FP16-FP32) artifact produces NaN, the PASA artifact stays
//!    finite — the adaptive guard's trigger condition, live.
//!
//! Run: cargo run --release --example overflow_study

use pasa::attention::{Allocation, AttentionRequest};
use pasa::experiments::{self, ExpOptions};
use pasa::numerics::finite_range;
use pasa::runtime::ModelRuntime;
use pasa::workloads::{all_traces, ResonanceCategory, ResonanceSpec};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let opts = ExpOptions {
        trace_scale: 8,
        ..Default::default()
    };

    println!("== model-shaped overflow traces (Figs. 11-14 substitutes) ==\n");
    for id in ["fig13", "fig14"] {
        println!("{}", experiments::run(id, &opts)?);
    }
    println!("{}", experiments::run("fig6", &opts)?);
    println!("{}", experiments::run("fig7", &opts)?);

    println!("== lab: end-to-end attention on the traces ==");
    for t in all_traces(opts.trace_scale) {
        let req =
            AttentionRequest::from_case(&t.generate(opts.seed), Allocation::Fa16_32)
                .with_fp16_inputs();
        let fa = req.run();
        let pasa_o = req.clone().with_alloc(Allocation::Pasa16).run();
        println!(
            "  {:<12} FA(FP16-FP32) overflow={} (max |S|={:.3e})  \
             PASA overflow={}  PASA out range={:?}",
            t.name,
            fa.overflowed(),
            fa.max_abs_score(),
            pasa_o.overflowed(),
            finite_range(&pasa_o.heads[0].data)
        );
    }

    println!("\n== runtime: resonant case through the AOT head kernels ==");
    let art = Path::new("artifacts");
    if !art.join("manifest.txt").exists() {
        println!("artifacts/ missing — run `make artifacts` first; skipping");
        return Ok(());
    }
    let rt = ModelRuntime::load(art)?;
    // Resonant inputs sized for the head module (512, 128).
    let spec = ResonanceSpec {
        s1: 512,
        s2: 512,
        d: 128,
        wavelength: 7.0,
        amp_q: 9.0,
        amp_k: 340.0,
        bias_q: 3.0,
        bias_k: -55.0,
        noise: 1.0,
        category: ResonanceCategory::AntiPhase,
        participation: 0.85,
        flip_fraction: 0.04,
        flip_amp_scale: 0.13,
    };
    let case = spec.generate(11);
    let fa = rt.head("fa16_32", &case.q.data, &case.k.data, &case.v.data)?;
    let pasa_o = rt.head("pasa", &case.q.data, &case.k.data, &case.v.data)?;
    println!(
        "  FA(FP16-FP32) head: non-finite outputs = {}",
        fa.iter().filter(|x| !x.is_finite()).count()
    );
    println!(
        "  PASA head:          non-finite outputs = {} (range {:?})",
        pasa_o.iter().filter(|x| !x.is_finite()).count(),
        finite_range(&pasa_o)
    );
    println!("overflow_study OK");
    Ok(())
}
