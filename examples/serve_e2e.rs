//! End-to-end serving validation (deliverable (b)/EXPERIMENTS.md §E2E).
//!
//! Loads the small trained transformer (AOT artifacts + weights.bin),
//! serves a batched workload of templated prompts through the full stack
//! (router → paged KV cache → continuous batcher → PJRT decode), and
//! reports:
//!   * latency/throughput metrics per guard policy,
//!   * greedy-output parity between PASA(FP16) and FA(FP32) attention —
//!     the paper's Fig. 8 / Appendix G check ("the inference accuracy with
//!     PASA is almost same with the reference"),
//!   * the training loss curve recorded at build time.
//!
//! Run: cargo run --release --example serve_e2e

use pasa::coordinator::{Engine, EngineConfig, GenParams, GuardPolicy, Request};
use pasa::model::Sampling;
use pasa::runtime::ModelRuntime;
use std::path::Path;
use std::time::Instant;

fn run_policy(
    rt: &ModelRuntime,
    policy: GuardPolicy,
    prompts: &[String],
    max_new: usize,
) -> anyhow::Result<(Vec<String>, String, f64)> {
    let mut cfg = EngineConfig::default();
    cfg.policy = policy;
    let mut eng = Engine::new(rt, cfg);
    for p in prompts {
        let id = eng.fresh_id();
        eng.submit(Request::new(id, p.clone()).with_params(GenParams {
            max_new_tokens: max_new,
            sampling: Sampling::Greedy,
            stop_at_eos: true,
        }));
    }
    let t0 = Instant::now();
    let mut comps = eng.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();
    comps.sort_by_key(|c| c.id);
    let texts = comps.iter().map(|c| c.text.clone()).collect();
    Ok((texts, eng.metrics.report(), wall))
}

fn main() -> anyhow::Result<()> {
    let art = Path::new("artifacts");
    if !art.join("manifest.txt").exists() {
        println!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }

    // Training loss curve (recorded by python/compile/train.py).
    if let Ok(curve) = std::fs::read_to_string(art.join("loss_curve.txt")) {
        let lines: Vec<&str> = curve.lines().collect();
        println!("== training loss curve (build-time) ==");
        if lines.len() > 6 {
            for l in lines.iter().take(4) {
                println!("  {l}");
            }
            println!("  ...");
            for l in lines.iter().rev().take(2).rev() {
                println!("  {l}");
            }
        } else {
            println!("{curve}");
        }
    }

    let rt = ModelRuntime::load(art)?;
    println!("\nmodel: {:?}", rt.dims);

    let prompts: Vec<String> = (0..12)
        .map(|i| match i % 3 {
            0 => format!("math: {} plus {} equals", i % 5, (i * 7 + 2) % 5),
            1 => format!(
                "count up: {}",
                ["zero", "one", "two", "three", "four", "five"][i % 6]
            ),
            _ => format!(
                "recall {} maps to",
                ["zero", "one", "two", "three", "four", "five", "six", "seven", "eight", "nine"]
                    [(i * 3) % 10]
            ),
        })
        .collect();

    println!("\n== serving: PASA(FP16) attention ==");
    let (texts_pasa, report_pasa, wall_pasa) =
        run_policy(&rt, GuardPolicy::AlwaysPasa, &prompts, 24)?;
    println!("{report_pasa}");
    println!("wall {wall_pasa:.2}s");

    println!("\n== serving: FA(FP32) reference attention ==");
    let (texts_fa32, report_fa32, wall_fa32) =
        run_policy(&rt, GuardPolicy::AlwaysFa32, &prompts, 24)?;
    println!("{report_fa32}");
    println!("wall {wall_fa32:.2}s");

    println!("\n== serving: adaptive guard (fast path + PASA on overflow) ==");
    let (_texts_ad, report_ad, wall_ad) = run_policy(&rt, GuardPolicy::Adaptive, &prompts, 24)?;
    println!("{report_ad}");
    println!("wall {wall_ad:.2}s");

    // Fig. 8 / Appendix G parity: greedy decodes under low-precision PASA
    // must match the high-precision reference.
    println!("\n== output parity: PASA(FP16) vs FA(FP32) (paper Fig. 8 check) ==");
    let mut matches = 0;
    for (i, (a, b)) in texts_pasa.iter().zip(&texts_fa32).enumerate() {
        let ok = a == b;
        matches += ok as usize;
        println!(
            "  [{i:>2}] {:<32} pasa={a:?}{}",
            prompts[i],
            if ok { String::new() } else { format!("  fa32={b:?}  <-- DIVERGED") }
        );
    }
    println!(
        "\nparity: {matches}/{} greedy outputs identical",
        texts_pasa.len()
    );
    println!("serve_e2e OK");
    Ok(())
}
