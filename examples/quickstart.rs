//! Quickstart: the three layers in one page.
//!
//! 1. The rust attention lab (bit-exact FP16 emulation) through the
//!    unified kernel API: build an `AttentionRequest` (masked, GQA,
//!    multi-head), dispatch it through `KernelRegistry`, and read the
//!    overflow telemetry the adaptive guard consumes. The paper's
//!    headline behaviour falls out: partially-low-precision FA overflows
//!    on biased data; PASA — same request, different allocation — does
//!    not.
//! 2. The AOT runtime loads the Pallas-built HLO head kernels and runs the
//!    same comparison through PJRT (requires `make artifacts`).
//!
//! Run: cargo run --release --example quickstart

use pasa::attention::{Allocation, AttentionRequest, AttnMask, KernelRegistry};
use pasa::coordinator::GuardSignal;
use pasa::numerics::relative_rmse;
use pasa::runtime::ModelRuntime;
use pasa::workloads::{gen_gqa_multihead, Distribution};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    println!("== 1. attention lab (software FP16, unified kernel API) ==");
    // The paper's Fig 9(a) overflow point — uniform mean 30, amplitude
    // 0.5 — as a GQA workload: 8 causal query heads over 2 KV heads.
    let dist = Distribution::Uniform { x0: 30.0, am: 0.5 };
    let mh = gen_gqa_multihead(dist, 8, 2, 256, 256, 128, 7);
    let req = AttentionRequest::from_multihead(&mh, Allocation::Fa16_32)
        .with_mask(AttnMask::Causal)
        .with_fp16_inputs();
    println!(
        "request: {} heads / {} KV heads, mask={}, seq {}x{}, d={}",
        req.n_heads(),
        req.n_kv_heads(),
        req.mask.label(),
        req.seq_q(),
        req.seq_kv(),
        req.head_dim()
    );

    let golden = KernelRegistry::naive().forward(&req);

    let fa = req.run();
    let fa_sig = GuardSignal::from_attention(&fa);
    println!(
        "FA(FP16-FP32): overflow = {} ({} pre-store events, max |S| = {:.3e}) \
         — the guard's replay trigger",
        fa.overflowed(),
        fa_sig.overflow_events,
        fa_sig.max_abs_score
    );

    // Same request, PASA allocation — the drop-in replacement claim.
    let pasa_out = req.clone().with_alloc(Allocation::Pasa16).run();
    let mut worst = 0.0f64;
    for h in 0..req.n_heads() {
        worst = worst.max(relative_rmse(
            &pasa_out.heads[h].data,
            &golden.heads[h].data,
        ));
    }
    println!(
        "PASA(FP16):    overflow = {}, max |S'| = {:.3e} (shift collapsed the bias), \
         worst head RMSE vs golden = {:.3e}",
        pasa_out.overflowed(),
        pasa_out.max_abs_score(),
        worst
    );

    println!("\n== 2. AOT runtime (PJRT, Pallas-built kernels) ==");
    let art = Path::new("artifacts");
    if !art.join("manifest.txt").exists() {
        println!("artifacts/ missing — run `make artifacts` first; skipping runtime demo");
        return Ok(());
    }
    let rt = ModelRuntime::load(art)?;
    // Benign inputs through the pasa and fa32 head modules.
    let n = 512 * 128;
    let mut rng = pasa::workloads::Pcg64::new(8, 0);
    let q: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let k: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let o_pasa = rt.head("pasa", &q, &k, &v)?;
    let o_fa32 = rt.head("fa32", &q, &k, &v)?;
    println!(
        "head kernels agree: PASA-vs-FA32 RMSE = {:.3e}",
        relative_rmse(&o_pasa, &o_fa32)
    );
    println!("quickstart OK");
    Ok(())
}
