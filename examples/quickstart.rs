//! Quickstart: the three layers in one page.
//!
//! 1. The rust attention lab (bit-exact FP16 emulation) shows the paper's
//!    headline behaviour: partially-low-precision FA overflows on biased
//!    data; PASA does not.
//! 2. The AOT runtime loads the Pallas-built HLO head kernels and runs the
//!    same comparison through PJRT (requires `make artifacts`).
//!
//! Run: cargo run --release --example quickstart

use pasa::attention::{
    flash_attention, naive_attention_f32, pasa_attention, to_fp16_inputs, Allocation,
    AttentionConfig,
};
use pasa::numerics::{has_overflow, relative_rmse};
use pasa::runtime::ModelRuntime;
use pasa::workloads::{gen_case, Distribution, Pcg64};
use std::path::Path;

fn main() -> anyhow::Result<()> {
    println!("== 1. attention lab (software FP16) ==");
    // The paper's Fig 9(a) overflow point: uniform mean 30, amplitude 0.5.
    let dist = Distribution::Uniform { x0: 30.0, am: 0.5 };
    let mut rng = Pcg64::new(7, 0);
    let case = to_fp16_inputs(&gen_case(dist, 512, 512, 128, &mut rng));
    let golden = naive_attention_f32(&case);

    let fa = flash_attention(&case, &AttentionConfig::new(Allocation::Fa16_32));
    println!(
        "FA(FP16-FP32): overflow = {} (paper: overflows at x0=30)",
        has_overflow(&fa.data)
    );
    let pasa_out = pasa_attention(&case, &AttentionConfig::new(Allocation::Pasa16));
    println!(
        "PASA(FP16):    overflow = {}, RMSE vs golden = {:.3e}",
        has_overflow(&pasa_out.data),
        relative_rmse(&pasa_out.data, &golden.data)
    );

    println!("\n== 2. AOT runtime (PJRT, Pallas-built kernels) ==");
    let art = Path::new("artifacts");
    if !art.join("manifest.txt").exists() {
        println!("artifacts/ missing — run `make artifacts` first; skipping runtime demo");
        return Ok(());
    }
    let rt = ModelRuntime::load(art)?;
    // Benign inputs through the pasa and fa32 head modules.
    let n = 512 * 128;
    let mut rng = Pcg64::new(8, 0);
    let q: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let k: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let v: Vec<f32> = (0..n).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    let o_pasa = rt.head("pasa", &q, &k, &v)?;
    let o_fa32 = rt.head("fa32", &q, &k, &v)?;
    println!(
        "head kernels agree: PASA-vs-FA32 RMSE = {:.3e}",
        relative_rmse(&o_pasa, &o_fa32)
    );
    println!("quickstart OK");
    Ok(())
}
